//! `quorall` — launcher CLI for the cyclic-quorum all-pairs engine.
//!
//! Subcommands:
//! * `quorum`     — generate/inspect quorum sets, emit the P = 4..111 table
//! * `pcit`       — run distributed (or single-node) PCIT on synthetic/CSV data
//! * `similarity` — distributed all-pairs similarity (top-k report)
//! * `nbody`      — placement-decomposed n-body demo
//! * `worker`     — join a TCP leader as one rank (spawned by the process launcher)
//! * `sim`        — analytic cluster-model predictions (Figure 2 extrapolation)
//! * `info`       — environment/runtime report
//!
//! The distributed commands take `--strategy {cyclic,grid,full}` to select
//! the placement the engine runs under, and `--transport {memory,tcp}` to
//! run the ranks over in-process channels or real loopback sockets with
//! heartbeat failure detection.

use quorall::cli::{App, ArgSpec, Command, ParseOutcome, Parsed};
use quorall::config::{BackendKind, DatasetConfig, PcitMode, RunConfig};
use quorall::coordinator::{
    distributed_report_json, engine_report_json, run_distributed_pcit, run_single_node,
    DegradeMode, EngineOptions, KillAt, TransportKind,
};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::metrics::Table;
use quorall::quorum::{self, CyclicQuorumSet, Strategy};
use quorall::util::bytes::format_bytes;
use quorall::util::timer::format_secs;

fn app() -> App {
    App::new("quorall", "cyclic-quorum all-pairs engine (Kleinheksel & Somani 2016)")
        .command(
            Command::new("quorum", "generate and analyze cyclic quorum sets")
                .arg(ArgSpec::opt("p", "number of processes", "16"))
                .arg(ArgSpec::opt("n", "elements for replication report", "1600"))
                .arg(ArgSpec::flag("table", "emit the P range table"))
                .arg(ArgSpec::opt("from", "table start P", "4"))
                .arg(ArgSpec::opt("to", "table end P", "111"))
                .arg(ArgSpec::flag("emit-rust", "emit tables.rs initializer rows")),
        )
        // UX-only pcit flags, exempt from the flag ↔ [run]-key parity lint
        // (`cargo xtask analyze`):
        // analyze: ignore(flag config): selects the TOML file itself, not a [run] knob
        // analyze: ignore(flag csv): dataset source override — [dataset] path in TOML
        // analyze: ignore(flag out): output path, not run configuration
        // analyze: ignore(flag verify): cross-check switch, not run configuration
        // analyze: ignore(flag jsonl): output format switch, not run configuration
        .command(
            Command::new("pcit", "run PCIT gene-network reconstruction")
                .arg(ArgSpec::opt("config", "TOML config path (overrides flags)", ""))
                .arg(ArgSpec::opt("ranks", "simulated MPI ranks", "8"))
                .arg(ArgSpec::opt("threads-per-rank", "compute threads per rank (0 = default)", ""))
                .arg(ArgSpec::opt("block", "tile block size override (0 = auto)", ""))
                .arg(ArgSpec::opt("genes", "synthetic gene count", "512"))
                .arg(ArgSpec::opt("samples", "synthetic sample count", "32"))
                .arg(ArgSpec::opt("mode", "single | quorum-exact | quorum-local", "quorum-exact"))
                .arg(ArgSpec::opt("strategy", "placement: cyclic | grid | full", "cyclic"))
                .arg(ArgSpec::opt("pipeline", "overlap compute with ring exchange: on | off", ""))
                .arg(ArgSpec::opt("scatter", "block scatter: streamed | monolithic", ""))
                .arg(ArgSpec::opt("redundancy", "owners per pair (r-fold placement)", ""))
                .arg(ArgSpec::opt("kill", "failure injection: ranks to crash, e.g. 4 or 2,5", ""))
                .arg(ArgSpec::opt(
                    "kill-at",
                    "phase: scatter | compute:<k> | gather | disconnect[:<k>] (comma-list = one per victim)",
                    "",
                ))
                .arg(ArgSpec::opt("recover", "re-assign a dead rank's tasks mid-run: on | off", ""))
                .arg(ArgSpec::opt("steal", "re-grant queued tasks to idle ranks: on | off", ""))
                .arg(ArgSpec::opt("steal-batch", "max queued tasks one steal grant may move", ""))
                .arg(ArgSpec::opt(
                    "throttle",
                    "deterministic slow rank: <rank>:<factor>, e.g. 3:4",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "transport",
                    "rank transport: memory | tcp (loopback sockets)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "processes",
                    "TCP only: one OS process per rank (the launcher): on | off",
                    "",
                ))
                .arg(ArgSpec::opt("heartbeat-ms", "TCP heartbeat interval (ms)", ""))
                .arg(ArgSpec::opt(
                    "heartbeat-timeout-ms",
                    "TCP silence window before a peer is declared dead (ms)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "degrade",
                    "when redundancy is exhausted: abort | partial (finish coverable pairs)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "rejoin-after-ms",
                    "disconnect-killed ranks rejoin the mesh after this delay (ms)",
                    "",
                ))
                .arg(ArgSpec::opt("backend", "native | xla", "native"))
                .arg(ArgSpec::opt("artifacts-dir", "backend artifact cache directory", ""))
                .arg(ArgSpec::opt("seed", "dataset seed", "42"))
                .arg(ArgSpec::opt("csv", "load expression CSV instead of synthetic", ""))
                .arg(ArgSpec::opt("out", "write surviving edges CSV here", ""))
                .arg(ArgSpec::flag("verify", "also run single-node and compare"))
                .arg(ArgSpec::flag("jsonl", "emit one machine-readable JSON report line")),
        )
        .command(
            Command::new("similarity", "distributed all-pairs similarity (top-k report)")
                .arg(ArgSpec::opt("subjects", "number of feature vectors", "256"))
                .arg(ArgSpec::opt("dim", "embedding dimension", "64"))
                .arg(ArgSpec::opt("ranks", "simulated ranks", "8"))
                .arg(ArgSpec::opt("strategy", "placement: cyclic | grid | full", "cyclic"))
                .arg(ArgSpec::opt("pipeline", "overlap compute with result gather: on | off", ""))
                .arg(ArgSpec::opt("scatter", "block scatter: streamed | monolithic", ""))
                .arg(ArgSpec::opt("redundancy", "owners per pair (r-fold placement)", ""))
                .arg(ArgSpec::opt("kill", "failure injection: ranks to crash, e.g. 4 or 2,5", ""))
                .arg(ArgSpec::opt(
                    "kill-at",
                    "phase: scatter | compute:<k> | gather | disconnect[:<k>] (comma-list = one per victim)",
                    "",
                ))
                .arg(ArgSpec::opt("recover", "re-assign a dead rank's tasks mid-run: on | off", ""))
                .arg(ArgSpec::opt("steal", "re-grant queued tasks to idle ranks: on | off", ""))
                .arg(ArgSpec::opt("steal-batch", "max queued tasks one steal grant may move", ""))
                .arg(ArgSpec::opt(
                    "throttle",
                    "deterministic slow rank: <rank>:<factor>, e.g. 3:4",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "transport",
                    "rank transport: memory | tcp (loopback sockets)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "processes",
                    "TCP only: one OS process per rank (the launcher): on | off",
                    "",
                ))
                .arg(ArgSpec::opt("heartbeat-ms", "TCP heartbeat interval (ms)", ""))
                .arg(ArgSpec::opt(
                    "heartbeat-timeout-ms",
                    "TCP silence window before a peer is declared dead (ms)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "degrade",
                    "when redundancy is exhausted: abort | partial (finish coverable pairs)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "rejoin-after-ms",
                    "disconnect-killed ranks rejoin the mesh after this delay (ms)",
                    "",
                ))
                .arg(ArgSpec::opt("topk", "pairs to report", "10"))
                .arg(ArgSpec::opt("seed", "feature seed", "42"))
                .arg(ArgSpec::opt("backend", "native | xla", "native"))
                .arg(ArgSpec::flag("jsonl", "emit one machine-readable JSON report line")),
        )
        .command(
            Command::new("nbody", "placement-decomposed n-body simulation")
                .arg(ArgSpec::opt("bodies", "number of bodies", "256"))
                .arg(ArgSpec::opt("ranks", "simulated ranks", "8"))
                .arg(ArgSpec::opt("strategy", "placement: cyclic | grid | full", "cyclic"))
                .arg(ArgSpec::opt("pipeline", "overlap compute with result gather: on | off", ""))
                .arg(ArgSpec::opt("scatter", "block scatter: streamed | monolithic", ""))
                .arg(ArgSpec::opt("redundancy", "owners per pair (r-fold placement)", ""))
                .arg(ArgSpec::opt("kill", "failure injection: ranks to crash, e.g. 4 or 2,5", ""))
                .arg(ArgSpec::opt(
                    "kill-at",
                    "phase: scatter | compute:<k> | gather | disconnect[:<k>] (comma-list = one per victim)",
                    "",
                ))
                .arg(ArgSpec::opt("recover", "re-assign a dead rank's tasks mid-run: on | off", ""))
                .arg(ArgSpec::opt("steal", "re-grant queued tasks to idle ranks: on | off", ""))
                .arg(ArgSpec::opt("steal-batch", "max queued tasks one steal grant may move", ""))
                .arg(ArgSpec::opt(
                    "throttle",
                    "deterministic slow rank: <rank>:<factor>, e.g. 3:4",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "transport",
                    "rank transport: memory | tcp (loopback sockets)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "processes",
                    "TCP only: one OS process per rank (the launcher): on | off",
                    "",
                ))
                .arg(ArgSpec::opt("heartbeat-ms", "TCP heartbeat interval (ms)", ""))
                .arg(ArgSpec::opt(
                    "heartbeat-timeout-ms",
                    "TCP silence window before a peer is declared dead (ms)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "degrade",
                    "when redundancy is exhausted: abort | partial (finish coverable pairs)",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "rejoin-after-ms",
                    "disconnect-killed ranks rejoin the mesh after this delay (ms)",
                    "",
                ))
                .arg(ArgSpec::opt("steps", "leapfrog steps", "50"))
                .arg(ArgSpec::opt("dt", "time step", "0.001"))
                .arg(ArgSpec::opt("threads", "pool threads", "4"))
                .arg(ArgSpec::flag("jsonl", "emit one machine-readable JSON report line")),
        )
        .command(
            Command::new(
                "worker",
                "join a TCP leader as one worker rank (spawned by the process launcher)",
            )
            .arg(ArgSpec::req("join", "leader address (host:port)"))
            .arg(ArgSpec::req("rank", "worker rank to claim"))
            .arg(ArgSpec::opt(
                "join-timeout-ms",
                "give up dialing the leader after this long",
                "10000",
            )),
        )
        .command(
            Command::new("sim", "analytic cluster predictions (Fig. 2 extrapolation)")
                .arg(ArgSpec::opt("genes", "gene count", "2000"))
                .arg(ArgSpec::opt("samples", "sample count", "48"))
                .arg(ArgSpec::opt("strategy", "placement: cyclic | grid | full", "cyclic"))
                .arg(ArgSpec::opt("max-ranks", "largest P to predict", "64")),
        )
        .command(
            Command::new("dataset", "generate a synthetic expression dataset as CSV")
                .arg(ArgSpec::opt("genes", "gene count", "512"))
                .arg(ArgSpec::opt("samples", "sample count", "48"))
                .arg(ArgSpec::opt("modules", "planted correlated modules", "12"))
                .arg(ArgSpec::opt("noise", "noise level", "0.6"))
                .arg(ArgSpec::opt("seed", "generator seed", "42"))
                .arg(ArgSpec::req("out", "output CSV path")),
        )
        .command(Command::new("info", "environment and artifact status"))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&argv) {
        ParseOutcome::Help(text) => print!("{text}"),
        ParseOutcome::Error(err, usage) => {
            eprintln!("error: {err}\n");
            eprint!("{usage}");
            std::process::exit(2);
        }
        ParseOutcome::Run(p) => {
            let result = match p.command {
                "quorum" => cmd_quorum(&p),
                "pcit" => cmd_pcit(&p),
                "similarity" => cmd_similarity(&p),
                "dataset" => cmd_dataset(&p),
                "nbody" => cmd_nbody(&p),
                "worker" => cmd_worker(&p),
                "sim" => cmd_sim(&p),
                "info" => cmd_info(),
                _ => unreachable!(),
            };
            if let Err(e) = result {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn cmd_quorum(p: &Parsed) -> anyhow::Result<()> {
    if p.get_flag("table") || p.get_flag("emit-rust") {
        let from = p.get_usize("from")?;
        let to = p.get_usize("to")?;
        let rows = quorum::tables::generate_table(from, to);
        if p.get_flag("emit-rust") {
            print!("{}", quorum::tables::emit_rust_table(&rows));
            return Ok(());
        }
        let n = p.get_usize("n")?;
        let mut t = Table::new(
            &format!("cyclic quorum sizes (N = {n} elements)"),
            &["P", "k", "lower_bound", "quorum N/proc", "force 2N/sqrtP", "all-data N", "savings_vs_force"],
        );
        for (pp, k, lb, set) in &rows {
            let q = CyclicQuorumSet::from_base_set(*pp, set.clone())?;
            let r = quorum::report(&q, n);
            t.row(vec![
                pp.to_string(),
                k.to_string(),
                lb.to_string(),
                r.elements_per_process.to_string(),
                r.force_elements_per_process.to_string(),
                n.to_string(),
                format!("{:.1}%", r.savings_vs_force_pct),
            ]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let pp = p.get_usize("p")?;
    let n = p.get_usize("n")?;
    let q = CyclicQuorumSet::for_processes(pp)?;
    println!("P = {pp}, base set A = {:?} (k = {})", q.base_set(), q.quorum_size());
    println!("all-pairs property: {}", q.verify_all_pairs_property());
    println!("intersection property: {}", q.verify_intersection_property());
    for i in 0..pp.min(8) {
        println!("  S_{i} = {:?}", q.quorum(i));
    }
    if pp > 8 {
        println!("  … ({} more)", pp - 8);
    }
    let r = quorum::report(&q, n);
    println!(
        "replication for N = {n}: {}/process (force: {}, all-data: {}), savings vs force: {:.1}%",
        r.elements_per_process, r.force_elements_per_process, n, r.savings_vs_force_pct
    );
    Ok(())
}

/// `--pipeline` tri-state: `""` inherits the config / `QUORALL_PIPELINE`
/// default, `on`/`off` are explicit.
fn parse_pipeline_flag(p: &Parsed) -> anyhow::Result<Option<bool>> {
    match p.get_str("pipeline").unwrap_or("") {
        "" => Ok(None),
        s => quorall::config::parse_pipeline(s)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("bad --pipeline: {s} (on | off)")),
    }
}

/// `--scatter` tri-state: `""` inherits the config / `QUORALL_SCATTER`
/// default, `streamed`/`monolithic` are explicit.
fn parse_scatter_flag(p: &Parsed) -> anyhow::Result<Option<bool>> {
    match p.get_str("scatter").unwrap_or("") {
        "" => Ok(None),
        s => quorall::config::parse_scatter(s)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("bad --scatter: {s} (streamed | monolithic)")),
    }
}

/// Failure-injection / recovery / transport flags shared by the
/// distributed commands. Every field is tri-state (`None` = flag not
/// passed — inherit the config / engine default), so an explicit
/// `--kill-at scatter` or `--transport memory` still overrides a config
/// file. `--kill-at` takes a comma list with one phase per `--kill`
/// victim; a single phase applies to all of them.
struct ResilienceFlags {
    redundancy: Option<usize>,
    kill: Option<Vec<usize>>,
    kill_at: Option<Vec<KillAt>>,
    recover: Option<bool>,
    steal: Option<bool>,
    steal_batch: Option<usize>,
    /// Outer `None` = flag not passed; `Some(t)` = explicit throttle.
    throttle: Option<Option<(usize, u32)>>,
    transport: Option<TransportKind>,
    processes: Option<bool>,
    heartbeat_ms: Option<u64>,
    heartbeat_timeout_ms: Option<u64>,
    degrade: Option<DegradeMode>,
    rejoin_after_ms: Option<u64>,
}

fn parse_resilience_flags(p: &Parsed) -> anyhow::Result<ResilienceFlags> {
    let redundancy = match p.get_str("redundancy").unwrap_or("") {
        "" => None,
        s => match s.parse::<usize>() {
            Ok(r) if r >= 1 => Some(r),
            _ => anyhow::bail!("bad --redundancy: {s} (want an integer >= 1)"),
        },
    };
    let kill = match p.get_str("kill").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_kill_list(s)
                .ok_or_else(|| anyhow::anyhow!("bad --kill: {s} (want e.g. 4 or 2,5)"))?,
        ),
    };
    let kill_at = match p.get_str("kill-at").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_kill_at_list(s)
                .filter(|v| !v.is_empty())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "bad --kill-at: {s} (scatter | compute:<k> | gather | disconnect[:<k>], \
                         comma-separated for one phase per --kill victim)"
                    )
                })?,
        ),
    };
    let recover = match p.get_str("recover").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_pipeline(s)
                .ok_or_else(|| anyhow::anyhow!("bad --recover: {s} (on | off)"))?,
        ),
    };
    let steal = match p.get_str("steal").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_steal(s)
                .ok_or_else(|| anyhow::anyhow!("bad --steal: {s} (on | off)"))?,
        ),
    };
    let steal_batch = match p.get_str("steal-batch").unwrap_or("") {
        "" => None,
        s => match s.parse::<usize>() {
            Ok(k) if k >= 1 => Some(k),
            _ => anyhow::bail!("bad --steal-batch: {s} (want an integer >= 1)"),
        },
    };
    let throttle = match p.get_str("throttle").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_throttle(s)
                .ok_or_else(|| anyhow::anyhow!("bad --throttle: {s} (want <rank>:<factor>)"))?,
        ),
    };
    let transport = match p.get_str("transport").unwrap_or("") {
        "" => None,
        s => Some(
            TransportKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("bad --transport: {s} (memory | tcp)"))?,
        ),
    };
    let processes = match p.get_str("processes").unwrap_or("") {
        "" => None,
        s => Some(
            quorall::config::parse_pipeline(s)
                .ok_or_else(|| anyhow::anyhow!("bad --processes: {s} (on | off)"))?,
        ),
    };
    let heartbeat_ms = match p.get_str("heartbeat-ms").unwrap_or("") {
        "" => None,
        _ => Some(p.get_u64("heartbeat-ms")?),
    };
    let heartbeat_timeout_ms = match p.get_str("heartbeat-timeout-ms").unwrap_or("") {
        "" => None,
        _ => Some(p.get_u64("heartbeat-timeout-ms")?),
    };
    let degrade = match p.get_str("degrade").unwrap_or("") {
        "" => None,
        s => Some(
            DegradeMode::parse(s)
                .ok_or_else(|| anyhow::anyhow!("bad --degrade: {s} (abort | partial)"))?,
        ),
    };
    let rejoin_after_ms = match p.get_str("rejoin-after-ms").unwrap_or("") {
        "" => None,
        _ => Some(p.get_u64("rejoin-after-ms")?),
    };
    Ok(ResilienceFlags {
        redundancy,
        kill,
        kill_at,
        recover,
        steal,
        steal_batch,
        throttle,
        transport,
        processes,
        heartbeat_ms,
        heartbeat_timeout_ms,
        degrade,
        rejoin_after_ms,
    })
}

impl ResilienceFlags {
    fn apply_to_opts(&self, opts: &mut EngineOptions) {
        if let Some(r) = self.redundancy {
            opts.redundancy = r;
        }
        if let Some(kill) = &self.kill {
            opts.kill = kill.clone();
        }
        if let Some(phases) = &self.kill_at {
            if phases.len() == 1 {
                opts.kill_at = phases[0];
                opts.kill_at_list.clear();
            } else {
                opts.kill_at_list = phases.clone();
            }
        }
        if let Some(r) = self.recover {
            opts.recover = r;
        }
        if let Some(s) = self.steal {
            opts.steal = s;
        }
        if let Some(k) = self.steal_batch {
            opts.steal_batch = k;
        }
        if let Some(t) = self.throttle {
            opts.throttle = t;
        }
        if let Some(t) = self.transport {
            opts.transport = t;
        }
        if let Some(b) = self.processes {
            opts.tcp_processes = b;
        }
        if let Some(ms) = self.heartbeat_ms {
            opts.heartbeat_ms = ms;
        }
        if let Some(ms) = self.heartbeat_timeout_ms {
            opts.heartbeat_timeout_ms = ms;
        }
        if let Some(d) = self.degrade {
            opts.degrade = d;
        }
        if let Some(ms) = self.rejoin_after_ms {
            opts.rejoin_after_ms = Some(ms);
        }
    }

    /// Same tri-state overlay for a `RunConfig` (the pcit command path).
    fn apply_to_cfg(&self, cfg: &mut RunConfig) {
        if let Some(r) = self.redundancy {
            cfg.redundancy = r;
        }
        if let Some(kill) = &self.kill {
            cfg.kill = kill.clone();
        }
        if let Some(phases) = &self.kill_at {
            if phases.len() == 1 {
                cfg.kill_at = phases[0];
                cfg.kill_at_list.clear();
            } else {
                cfg.kill_at_list = phases.clone();
            }
        }
        if let Some(r) = self.recover {
            cfg.recover = r;
        }
        if let Some(s) = self.steal {
            cfg.steal = s;
        }
        if let Some(k) = self.steal_batch {
            cfg.steal_batch = k;
        }
        if let Some(t) = self.throttle {
            cfg.throttle = t;
        }
        if let Some(t) = self.transport {
            cfg.transport = t;
        }
        if let Some(b) = self.processes {
            cfg.tcp_processes = b;
        }
        if let Some(ms) = self.heartbeat_ms {
            cfg.heartbeat_ms = ms;
        }
        if let Some(ms) = self.heartbeat_timeout_ms {
            cfg.heartbeat_timeout_ms = ms;
        }
        if let Some(d) = self.degrade {
            cfg.degrade = d;
        }
        if let Some(ms) = self.rejoin_after_ms {
            cfg.rejoin_after_ms = Some(ms);
        }
    }
}

fn load_dataset(p: &Parsed) -> anyhow::Result<ExpressionDataset> {
    let csv = p.get_str("csv").unwrap_or("");
    if !csv.is_empty() {
        let (m, _names) = quorall::data::loader::load_expression_csv(std::path::Path::new(csv))?;
        let spec = SyntheticSpec { genes: m.rows(), samples: m.cols(), modules: 0, noise: 0.0, seed: 0 };
        return Ok(ExpressionDataset { expr: m, module_of: vec![usize::MAX; spec.genes], spec });
    }
    Ok(ExpressionDataset::generate(SyntheticSpec {
        genes: p.get_usize("genes")?,
        samples: p.get_usize("samples")?,
        modules: (p.get_usize("genes")? / 64).max(2),
        noise: 0.6,
        seed: p.get_u64("seed")?,
    }))
}

fn cmd_pcit(p: &Parsed) -> anyhow::Result<()> {
    let mut cfg = if let Some(path) = p.get_str("config").filter(|s| !s.is_empty()) {
        RunConfig::from_file(std::path::Path::new(path)).map_err(|e| anyhow::anyhow!("{e}"))?
    } else {
        let mode = PcitMode::parse(p.get_str("mode").unwrap_or("quorum-exact"))
            .ok_or_else(|| anyhow::anyhow!("bad --mode"))?;
        let strategy = Strategy::parse(p.get_str("strategy").unwrap_or("cyclic"))
            .ok_or_else(|| anyhow::anyhow!("bad --strategy (cyclic | grid | full)"))?;
        let backend = BackendKind::parse(p.get_str("backend").unwrap_or("native"))
            .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
        let cfg = RunConfig {
            ranks: p.get_usize("ranks")?,
            mode,
            strategy,
            backend,
            seed: p.get_u64("seed")?,
            dataset: DatasetConfig::Synthetic {
                genes: p.get_usize("genes")?,
                samples: p.get_usize("samples")?,
                modules: (p.get_usize("genes")? / 64).max(2),
                noise: 0.6,
            },
            ..RunConfig::default()
        };
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        cfg
    };
    if let Some(b) = parse_pipeline_flag(p)? {
        cfg.pipeline = b;
    }
    if let Some(b) = parse_scatter_flag(p)? {
        cfg.streamed_scatter = b;
    }
    if let Some(v) = p.get_str("threads-per-rank").filter(|s| !s.is_empty()) {
        cfg.threads_per_rank =
            v.parse().map_err(|_| anyhow::anyhow!("bad --threads-per-rank: {v}"))?;
    }
    if let Some(v) = p.get_str("block").filter(|s| !s.is_empty()) {
        cfg.block = v.parse().map_err(|_| anyhow::anyhow!("bad --block: {v}"))?;
    }
    if let Some(v) = p.get_str("artifacts-dir").filter(|s| !s.is_empty()) {
        cfg.artifacts_dir = std::path::PathBuf::from(v);
    }
    parse_resilience_flags(p)?.apply_to_cfg(&mut cfg);
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    // A config file fully describes the dataset; flags otherwise.
    let dataset = if p.get_str("config").filter(|s| !s.is_empty()).is_some() {
        match &cfg.dataset {
            DatasetConfig::Synthetic { genes, samples, modules, noise } => {
                ExpressionDataset::generate(SyntheticSpec {
                    genes: *genes,
                    samples: *samples,
                    modules: *modules,
                    noise: *noise,
                    seed: cfg.seed,
                })
            }
            DatasetConfig::Csv { path } => {
                let (m, _names) = quorall::data::loader::load_expression_csv(path)?;
                let spec = SyntheticSpec { genes: m.rows(), samples: m.cols(), modules: 0, noise: 0.0, seed: 0 };
                ExpressionDataset { expr: m, module_of: vec![usize::MAX; spec.genes], spec }
            }
        }
    } else {
        load_dataset(p)?
    };
    println!(
        "PCIT: N = {} genes, M = {} samples, mode = {}, strategy = {}, pipeline = {}, scatter = {}, transport = {}, backend = {}, ranks = {}",
        dataset.genes(),
        dataset.samples(),
        cfg.mode.name(),
        cfg.strategy.name(),
        if cfg.pipeline { "on" } else { "off" },
        if cfg.streamed_scatter { "streamed" } else { "monolithic" },
        cfg.transport.name(),
        cfg.backend.name(),
        cfg.ranks
    );

    if cfg.mode == PcitMode::Single {
        // The baseline's parallelism is the intra-rank thread count
        // (flag > config > QUORALL_THREADS_PER_RANK env > 1), never the
        // rank count: single-node has no ranks to saturate.
        let threads = cfg.threads_per_rank.max(1);
        let rep = run_single_node(&dataset, threads, None);
        println!(
            "single-node: {} edges in {} with {} thread{} (logical memory {})",
            rep.network.n_edges(),
            format_secs(rep.wall_secs),
            threads,
            if threads == 1 { "" } else { "s" },
            format_bytes(rep.logical_bytes)
        );
        return Ok(());
    }

    if cfg.recover || !cfg.kill.is_empty() {
        println!(
            "resilience: r = {}, kill = {:?} at {}, recover = {}, degrade = {}{}",
            cfg.redundancy,
            cfg.kill,
            cfg.kill_at.name(),
            if cfg.recover { "on" } else { "off" },
            cfg.degrade.name(),
            match cfg.rejoin_after_ms {
                Some(ms) => format!(", rejoin after {ms} ms"),
                None => String::new(),
            }
        );
    }
    if cfg.steal || cfg.throttle.is_some() {
        println!(
            "scheduling: steal = {} (batch {}), throttle = {}",
            if cfg.steal { "on" } else { "off" },
            cfg.steal_batch,
            match cfg.throttle {
                Some((r, f)) => format!("rank {r} at {f}x"),
                None => "none".into(),
            }
        );
    }

    let exec = quorall::runtime::executor_for(cfg.backend, &cfg.artifacts_dir)?;
    let rep = run_distributed_pcit(&cfg, &dataset, exec)?;
    if !rep.dead_ranks.is_empty() {
        println!(
            "recovered from dead ranks {:?}: {} tasks re-assigned to surviving hosts",
            rep.dead_ranks, rep.recovered_tasks
        );
        for d in &rep.health.detections {
            println!(
                "  failure detector: rank {} dead ({}, detection latency {:.3}s)",
                d.rank, d.cause, d.latency_secs
            );
        }
    }
    if rep.ring_reroutes > 0 {
        println!(
            "ring re-routing: {} reroute order(s) — substitutes replayed the dead ranks' ring walks",
            rep.ring_reroutes
        );
    }
    if !rep.rejoined_ranks.is_empty() {
        println!(
            "rejoin: ranks {:?} re-admitted mid-run ({} duplicate result(s) discarded first-writer-wins)",
            rep.rejoined_ranks, rep.duplicate_results
        );
    }
    if !rep.uncovered_pairs.is_empty() {
        println!(
            "degraded completion: {} pair(s) uncoverable after redundancy exhaustion (coverage {:.2}%)",
            rep.uncovered_pairs.len(),
            100.0 * rep.coverage_ratio
        );
        for (a, b) in rep.uncovered_pairs.iter().take(16) {
            println!("  uncovered: ({a}, {b})");
        }
        if rep.uncovered_pairs.len() > 16 {
            println!("  … ({} more)", rep.uncovered_pairs.len() - 16);
        }
    }
    if rep.stolen_tasks > 0 {
        println!(
            "work stealing: {} tasks re-granted to idle ranks (mean grant-to-result {})",
            rep.stolen_tasks,
            format_secs(rep.steal_latency_secs)
        );
    }
    println!(
        "distributed: {} edges in {} | k = {} | peak mem/rank {} | comm {} (scatter {}) | blocked-recv {} (overlap {:.1}%) | first task at {}",
        rep.network.n_edges(),
        format_secs(rep.wall_secs),
        rep.quorum_size,
        format_bytes(rep.peak_bytes_per_rank),
        format_bytes(rep.total_comm_bytes),
        format_bytes(rep.scatter_comm_bytes),
        format_secs(rep.recv_blocked_secs),
        100.0 * rep.overlap_ratio,
        format_secs(rep.time_to_first_task_secs)
    );
    let mut t = Table::new("per-rank stats", &["rank", "corr_tiles", "elim_tiles", "peak_mem", "sent", "recv"]);
    for s in &rep.stats {
        t.row(vec![
            s.rank.to_string(),
            s.corr_tiles.to_string(),
            s.elim_tiles.to_string(),
            format_bytes(s.peak_logical_bytes),
            format_bytes(s.sent_bytes),
            format_bytes(s.recv_bytes),
        ]);
    }
    println!("{}", t.render());

    if p.get_flag("verify") {
        let single = run_single_node(&dataset, 4, None);
        let same = rep.network.same_edges(&single.network);
        println!(
            "verify vs single-node: {} ({} vs {} edges, jaccard {:.4})",
            if same { "IDENTICAL" } else { "DIFFERENT" },
            rep.network.n_edges(),
            single.network.n_edges(),
            rep.network.jaccard(&single.network)
        );
        if cfg.mode == PcitMode::QuorumExact && !same {
            anyhow::bail!("quorum-exact must match single-node exactly");
        }
    }
    if let Some(out) = p.get_str("out").filter(|s| !s.is_empty()) {
        quorall::data::loader::write_edges_csv(std::path::Path::new(out), &rep.network.edges)?;
        println!("wrote {out}");
    }
    if p.get_flag("jsonl") {
        let line = distributed_report_json(&rep).to_string();
        println!("{line}");
    }
    Ok(())
}

fn cmd_similarity(p: &Parsed) -> anyhow::Result<()> {
    use quorall::apps::similarity::{run_distributed_similarity, top_pairs};
    use quorall::util::prng::Rng;
    use quorall::util::Matrix;

    let n = p.get_usize("subjects")?;
    let dim = p.get_usize("dim")?;
    let ranks = p.get_usize("ranks")?;
    let k = p.get_usize("topk")?;
    let strategy = Strategy::parse(p.get_str("strategy").unwrap_or("cyclic"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy (cyclic | grid | full)"))?;
    let backend = BackendKind::parse(p.get_str("backend").unwrap_or("native"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))?;
    let exec = quorall::runtime::executor_for(backend, std::path::Path::new("artifacts"))?;

    let mut rng = Rng::new(p.get_u64("seed")?);
    let features = Matrix::from_fn(n, dim, |_, _| rng.normal_f32());
    let mut opts = EngineOptions::new(ranks, strategy);
    if let Some(b) = parse_pipeline_flag(p)? {
        opts.pipeline = b;
    }
    if let Some(b) = parse_scatter_flag(p)? {
        opts.streamed_scatter = b;
    }
    parse_resilience_flags(p)?.apply_to_opts(&mut opts);
    println!(
        "similarity: N = {n} × dim = {dim}, strategy = {}, pipeline = {}, scatter = {}, ranks = {ranks}, backend = {}",
        strategy.name(),
        if opts.pipeline { "on" } else { "off" },
        if opts.streamed_scatter { "streamed" } else { "monolithic" },
        exec.name()
    );
    let (sim, rep) = run_distributed_similarity(&features, &exec, &opts)?;
    if !rep.dead_ranks.is_empty() {
        println!(
            "recovered from dead ranks {:?}: {} tasks re-assigned to surviving hosts",
            rep.dead_ranks, rep.recovered_tasks
        );
    }
    println!(
        "distributed similarity ({}) in {} | replication k = {} | peak mem/rank {} | comm {} | blocked-recv {} (overlap {:.1}%)",
        rep.strategy.name(),
        format_secs(rep.wall_secs),
        rep.max_quorum_size,
        format_bytes(rep.peak_bytes_per_rank),
        format_bytes(rep.total_comm_bytes),
        format_secs(rep.recv_blocked_secs),
        100.0 * rep.overlap_ratio
    );
    let top = top_pairs(&sim, k);
    println!("top-{k} most similar pairs:");
    for (x, y, s) in &top {
        println!("  ({x:4}, {y:4})  sim = {s:.4}");
    }
    if p.get_flag("jsonl") {
        let line = engine_report_json(&rep).to_string();
        println!("{line}");
    }
    Ok(())
}

fn cmd_nbody(p: &Parsed) -> anyhow::Result<()> {
    use quorall::apps::nbody;
    let n = p.get_usize("bodies")?;
    let ranks = p.get_usize("ranks")?;
    let steps = p.get_usize("steps")?;
    let dt = p.get_f64("dt")?;
    let strategy = Strategy::parse(p.get_str("strategy").unwrap_or("cyclic"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy (cyclic | grid | full)"))?;
    let pool = quorall::pool::ThreadPool::new(p.get_usize("threads")?);
    let mut bodies = nbody::Bodies::random(n, 42);
    let e0 = bodies.total_energy();

    // One engine pass first: the distributed path with measured stats; its
    // forces then seed the simulation (no duplicate first force pass).
    let mut opts = EngineOptions::new(ranks, strategy);
    if let Some(b) = parse_pipeline_flag(p)? {
        opts.pipeline = b;
    }
    if let Some(b) = parse_scatter_flag(p)? {
        opts.streamed_scatter = b;
    }
    parse_resilience_flags(p)?.apply_to_opts(&mut opts);
    let (forces, rep) = nbody::run_distributed_nbody(&bodies, &opts)?;
    println!(
        "distributed forces ({}, pipeline = {}): peak mem/rank {} | comm {} | blocked-recv {}",
        rep.strategy.name(),
        if opts.pipeline { "on" } else { "off" },
        format_bytes(rep.peak_bytes_per_rank),
        format_bytes(rep.total_comm_bytes),
        format_secs(rep.recv_blocked_secs)
    );
    if !rep.dead_ranks.is_empty() {
        println!(
            "recovered from dead ranks {:?}: {} tasks re-assigned to surviving hosts",
            rep.dead_ranks, rep.recovered_tasks
        );
    }

    let sw = quorall::util::timer::Stopwatch::start();
    let drift =
        nbody::simulate_with_initial_forces(&mut bodies, ranks, strategy, steps, dt, &pool, forces)?;
    println!(
        "n-body: {n} bodies, {ranks} ranks ({} placement), {steps} steps in {} | E0 = {e0:.4}, relative energy drift = {drift:.2e}",
        strategy.name(),
        format_secs(sw.elapsed_secs())
    );
    if p.get_flag("jsonl") {
        let line = engine_report_json(&rep).to_string();
        println!("{line}");
    }
    Ok(())
}

/// `quorall worker --join <addr> --rank <r>`: one rank of a TCP process
/// cluster. The launcher (the leader process) spawns these; the join
/// Welcome's setup blob carries the plan shape and the app spec, so the
/// worker needs no dataset or config of its own — blocks arrive through
/// the scatter like on any other transport.
fn cmd_worker(p: &Parsed) -> anyhow::Result<()> {
    use quorall::coordinator::{endpoint_of, tcp, wire, Plan};
    use std::time::{Duration, Instant};

    let leader = p.get_str("join").unwrap_or_default().to_string();
    let rank = p.get_usize("rank")?;
    let timeout = Duration::from_millis(p.get_u64("join-timeout-ms")?);
    let joined = tcp::join(&leader, endpoint_of(rank), timeout)?;
    let (n, ranks, block, pipeline, streamed_scatter, steal, throttle, threads, spec) =
        wire::decode_setup(&joined.setup)?;
    let app = quorall::apps::app_from_spec(&spec)?;
    let plan = Plan {
        n,
        p: ranks,
        block,
        pipeline,
        streamed_scatter,
        steal,
        throttle,
        threads,
        t0: Instant::now(),
    };
    quorall::coordinator::worker::worker_main(joined.endpoint, app, plan);
    // An injected hard disconnect must leave this process's sockets open
    // and silent (peers detect it by heartbeat timeout, not EOF): park
    // until the launcher reaps us instead of exiting.
    while tcp::went_dark() {
        std::thread::sleep(Duration::from_secs(1));
    }
    Ok(())
}

fn cmd_sim(p: &Parsed) -> anyhow::Result<()> {
    use quorall::sim::{predict_placement, predict_single, ClusterModel};
    let n = p.get_usize("genes")?;
    let m = p.get_usize("samples")?;
    let maxp = p.get_usize("max-ranks")?;
    let strategy = Strategy::parse(p.get_str("strategy").unwrap_or("cyclic"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy (cyclic | grid | full)"))?;
    let model = ClusterModel::default();
    let single = predict_single(n, m, 16, &model);
    let mut t = Table::new(
        &format!(
            "cluster-model predictions (N = {n}, M = {m}, {} placement; single-node 16T = {})",
            strategy.name(),
            format_secs(single.total_secs)
        ),
        &["P", "nodes", "total", "speedup", "mem/rank"],
    );
    let mut pp = 4;
    while pp <= maxp {
        let pred = predict_placement(n, m, pp, strategy, &model)?;
        t.row(vec![
            pp.to_string(),
            pred.nodes.to_string(),
            format_secs(pred.total_secs),
            format!("{:.2}x", single.total_secs / pred.total_secs),
            format_bytes(pred.mem_bytes_per_rank),
        ]);
        pp *= 2;
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_dataset(p: &Parsed) -> anyhow::Result<()> {
    let spec = SyntheticSpec {
        genes: p.get_usize("genes")?,
        samples: p.get_usize("samples")?,
        modules: p.get_usize("modules")?,
        noise: p.get_f64("noise")?,
        seed: p.get_u64("seed")?,
    };
    let d = ExpressionDataset::generate(spec);
    let out = p.get_str("out").unwrap();
    quorall::data::loader::write_expression_csv(std::path::Path::new(out), &d.expr)?;
    println!(
        "wrote {} ({} genes x {} samples, {} module genes)",
        out,
        d.genes(),
        d.samples(),
        d.module_gene_count()
    );
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("quorall {}", env!("CARGO_PKG_VERSION"));
    println!("cores: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    let dir = std::path::Path::new("artifacts");
    match quorall::runtime::ArtifactManifest::load(dir) {
        Ok(m) => {
            println!("artifacts: {} kernels in {}", m.kernels.len(), dir.display());
            for (name, k) in &m.kernels {
                println!("  {name}: {} dims {:?}", k.file.display(), k.dims);
            }
        }
        Err(e) => println!("artifacts: not available ({e})"),
    }
    println!("peak RSS: {}", format_bytes(quorall::metrics::peak_rss_bytes()));
    Ok(())
}
