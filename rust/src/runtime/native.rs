//! Pure-Rust tile backend — reference semantics for the XLA artifacts.

use super::TileExecutor;
use crate::pcit::blocked::eliminate_chunk;
use crate::pcit::correlation::corr_block;
use crate::util::{Matrix, MatrixView};

/// Always-available backend computing tiles with the same formulas the
/// Pallas kernels implement. Operates directly on borrowed views — zero
/// operand copies per tile.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

impl TileExecutor for NativeBackend {
    fn corr_tile(&self, za: MatrixView<'_>, zb: MatrixView<'_>) -> Matrix {
        corr_block(za, zb)
    }

    fn pcit_tile(&self, cxy: MatrixView<'_>, rxz: MatrixView<'_>, ryz: MatrixView<'_>) -> Matrix {
        let mask = eliminate_chunk(cxy, rxz, ryz);
        let (a, b) = cxy.shape();
        Matrix::from_vec(a, b, mask.into_iter().map(|m| if m { 1.0 } else { 0.0 }).collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcit::standardize_rows;
    use crate::util::prng::Rng;

    #[test]
    fn corr_tile_matches_module_fn() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(6, 12, |_, _| rng.normal_f32());
        let z = standardize_rows(&x);
        let a = z.view_block(0, 0, 3, 12);
        let b = z.view_block(3, 0, 3, 12);
        let be = NativeBackend::new();
        assert_eq!(be.corr_tile(a, b), corr_block(a, b));
    }

    #[test]
    fn tile_from_views_equals_tile_from_copies() {
        // Zero-copy reads out of the standardized matrix must be exactly
        // the tiles the old copy-then-compute path produced.
        let mut rng = Rng::new(9);
        let x = Matrix::from_fn(20, 16, |_, _| rng.normal_f32());
        let z = standardize_rows(&x);
        let be = NativeBackend::new();
        let from_views = be.corr_tile(z.view_block(2, 0, 7, 16), z.view_block(11, 0, 5, 16));
        let (ca, cb) = (z.block(2, 0, 7, 16), z.block(11, 0, 5, 16));
        let from_copies = be.corr_tile(ca.view(), cb.view());
        assert_eq!(from_views.as_slice(), from_copies.as_slice());
    }

    #[test]
    fn pcit_tile_flags_are_binary() {
        let mut rng = Rng::new(5);
        let cxy = Matrix::from_fn(4, 4, |_, _| rng.f32() * 1.6 - 0.8);
        let rxz = Matrix::from_fn(4, 8, |_, _| rng.f32() * 1.6 - 0.8);
        let ryz = Matrix::from_fn(4, 8, |_, _| rng.f32() * 1.6 - 0.8);
        let be = NativeBackend::new();
        let f = be.pcit_tile(cxy.view(), rxz.view(), ryz.view());
        for &v in f.as_slice() {
            assert!(v == 0.0 || v == 1.0);
        }
    }
}
