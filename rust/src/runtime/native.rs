//! Pure-Rust tile backend — reference semantics for the XLA artifacts.

use super::TileExecutor;
use crate::pcit::blocked::eliminate_chunk;
use crate::pcit::correlation::corr_block;
use crate::util::Matrix;

/// Always-available backend computing tiles with the same formulas the
/// Pallas kernels implement.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

impl TileExecutor for NativeBackend {
    fn corr_tile(&self, za: &Matrix, zb: &Matrix) -> Matrix {
        corr_block(za, zb)
    }

    fn pcit_tile(&self, cxy: &Matrix, rxz: &Matrix, ryz: &Matrix) -> Matrix {
        let mask = eliminate_chunk(cxy, rxz, ryz);
        let (a, b) = cxy.shape();
        Matrix::from_vec(a, b, mask.into_iter().map(|m| if m { 1.0 } else { 0.0 }).collect())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcit::standardize_rows;
    use crate::util::prng::Rng;

    #[test]
    fn corr_tile_matches_module_fn() {
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(6, 12, |_, _| rng.normal_f32());
        let z = standardize_rows(&x);
        let a = z.block(0, 0, 3, 12);
        let b = z.block(3, 0, 3, 12);
        let be = NativeBackend::new();
        assert_eq!(be.corr_tile(&a, &b), corr_block(&a, &b));
    }

    #[test]
    fn pcit_tile_flags_are_binary() {
        let mut rng = Rng::new(5);
        let cxy = Matrix::from_fn(4, 4, |_, _| rng.f32() * 1.6 - 0.8);
        let rxz = Matrix::from_fn(4, 8, |_, _| rng.f32() * 1.6 - 0.8);
        let ryz = Matrix::from_fn(4, 8, |_, _| rng.f32() * 1.6 - 0.8);
        let be = NativeBackend::new();
        let f = be.pcit_tile(&cxy, &rxz, &ryz);
        for &v in f.as_slice() {
            assert!(v == 0.0 || v == 1.0);
        }
    }
}
