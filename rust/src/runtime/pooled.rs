//! Row-chunked, bitwise-stable pooled wrappers over the [`TileExecutor`]
//! kernels — the intra-rank half of the paper's hybrid MPI+OpenMP split.
//!
//! Both tile shapes decompose cleanly along the first operand's rows:
//! every output row of `corr_tile(za, zb)` depends only on the matching
//! `za` row (each element is an independent strict-order dot product), and
//! every output row of `pcit_tile(cxy, rxz, ryz)` depends only on the
//! matching `cxy` / `rxz` rows plus all of `ryz`. Chunking the row range
//! and stitching the per-chunk results back into their original slots
//! therefore reproduces the serial kernel **bit for bit**, for any chunk
//! boundaries — which is exactly what the self-scheduling
//! [`ThreadPool::parallel_for_chunked`] needs, since its boundaries depend
//! on thread count. Compute happens in parallel; the commit order is
//! irrelevant because every chunk writes a disjoint, position-fixed slice.
//!
//! Callers pass `Option<&ThreadPool>` (the shape of
//! [`WorkerCtx::tile_pool`](crate::coordinator::WorkerCtx::tile_pool));
//! `None` or a 1-thread pool falls straight through to the serial kernel.

use super::TileExecutor;
use crate::pool::{SendPtr, ThreadPool};
use crate::util::{Matrix, MatrixView};

/// Correlation tile `za (A×M) · zb (B×M)ᵀ`, row-chunked across `pool`.
/// Bitwise-identical to `exec.corr_tile(za, zb)` at any thread count.
pub fn corr_tile_pooled(
    exec: &dyn TileExecutor,
    pool: Option<&ThreadPool>,
    za: MatrixView<'_>,
    zb: MatrixView<'_>,
) -> Matrix {
    let (a, m) = za.shape();
    let b = zb.rows();
    let Some(pool) = pool.filter(|p| p.size() > 1 && a >= 2) else {
        return exec.corr_tile(za, zb);
    };
    let mut out = Matrix::zeros(a, b);
    // analyze: hot-path begin(pooled-tiles)
    {
        let out_ptr = SendPtr(out.as_mut_slice().as_mut_ptr());
        pool.parallel_for_chunked(a, |r| {
            let rows = exec.corr_tile(za.sub(r.start, 0, r.len(), m), zb);
            // SAFETY: each chunk writes the disjoint row range
            // `r.start..r.start + r.len()` of `out`, and `out` outlives the
            // blocking parallel_for_chunked call.
            // analyze: allow(unsafe): the SAFETY argument above is the audit
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(r.start * b), r.len() * b)
            };
            dst.copy_from_slice(rows.as_slice());
        });
    }
    out
}

/// PCIT elimination tile (`cxy` A×B, `rxz` A×Z, `ryz` B×Z → A×B flags),
/// row-chunked across `pool`: `cxy` and `rxz` chunk together along A, `ryz`
/// ships whole to every chunk (output row `a` scans all mediators `z`).
/// Bitwise-identical to `exec.pcit_tile(cxy, rxz, ryz)` at any thread count.
pub fn pcit_tile_pooled(
    exec: &dyn TileExecutor,
    pool: Option<&ThreadPool>,
    cxy: MatrixView<'_>,
    rxz: MatrixView<'_>,
    ryz: MatrixView<'_>,
) -> Matrix {
    let (a, b) = cxy.shape();
    let z = rxz.cols();
    let Some(pool) = pool.filter(|p| p.size() > 1 && a >= 2) else {
        return exec.pcit_tile(cxy, rxz, ryz);
    };
    let mut out = Matrix::zeros(a, b);
    {
        let out_ptr = SendPtr(out.as_mut_slice().as_mut_ptr());
        pool.parallel_for_chunked(a, |r| {
            let flags =
                exec.pcit_tile(cxy.sub(r.start, 0, r.len(), b), rxz.sub(r.start, 0, r.len(), z), ryz);
            // SAFETY: disjoint row ranges of `out`, which outlives the
            // blocking parallel_for_chunked call (same contract as above).
            // analyze: allow(unsafe): the SAFETY argument above is the audit
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(r.start * b), r.len() * b)
            };
            dst.copy_from_slice(flags.as_slice());
        });
    }
    // analyze: hot-path end(pooled-tiles)
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Rng;

    fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| rng.normal_f32())
    }

    #[test]
    fn corr_tile_pooled_is_bitwise_serial() {
        let exec = NativeBackend::new();
        let mut rng = Rng::new(7);
        // Skewed shapes on purpose: tall, wide, tiny, and 1-row tiles.
        for (a, b, m) in [(33, 17, 24), (5, 64, 8), (1, 9, 12), (64, 64, 16)] {
            let za = rand_matrix(&mut rng, a, m);
            let zb = rand_matrix(&mut rng, b, m);
            let serial = exec.corr_tile(za.view(), zb.view());
            for t in [2, 3, 4] {
                let pool = ThreadPool::new(t);
                let pooled = corr_tile_pooled(&exec, Some(&pool), za.view(), zb.view());
                assert_eq!(serial.as_slice(), pooled.as_slice(), "a={a} b={b} m={m} t={t}");
            }
            let fallback = corr_tile_pooled(&exec, None, za.view(), zb.view());
            assert_eq!(serial.as_slice(), fallback.as_slice());
        }
    }

    #[test]
    fn pcit_tile_pooled_is_bitwise_serial() {
        let exec = NativeBackend::new();
        let mut rng = Rng::new(11);
        for (a, b, z) in [(21, 13, 30), (4, 40, 10), (1, 6, 6)] {
            let cxy = rand_matrix(&mut rng, a, b);
            let rxz = rand_matrix(&mut rng, a, z);
            let ryz = rand_matrix(&mut rng, b, z);
            let serial = exec.pcit_tile(cxy.view(), rxz.view(), ryz.view());
            for t in [2, 4] {
                let pool = ThreadPool::new(t);
                let pooled =
                    pcit_tile_pooled(&exec, Some(&pool), cxy.view(), rxz.view(), ryz.view());
                assert_eq!(serial.as_slice(), pooled.as_slice(), "a={a} b={b} z={z} t={t}");
            }
            let fallback = pcit_tile_pooled(&exec, None, cxy.view(), rxz.view(), ryz.view());
            assert_eq!(serial.as_slice(), fallback.as_slice());
        }
    }
}
