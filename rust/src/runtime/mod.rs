//! Tile-execution runtime: the bridge between the Rust coordinator (L3)
//! and the AOT-compiled JAX/Pallas compute (L2/L1).
//!
//! [`TileExecutor`] is the interface workers program against. Two
//! implementations:
//! * [`native::NativeBackend`] — pure Rust, always available; the
//!   reference semantics (identical to `pcit::correlation` / `pcit::blocked`).
//! * [`engine::XlaBackend`] — loads `artifacts/*.hlo.txt` (produced by
//!   `python/compile/aot.py`), compiles them on the PJRT CPU client from the
//!   `xla` crate, and executes tiles with padding to the artifacts' static
//!   shapes.
//!
//! Differential tests (`rust/tests/integration_runtime.rs`) assert the two
//! backends agree on random tiles.

pub mod native;
pub mod artifact;
pub mod engine;
pub mod pooled;

pub use artifact::{ArtifactManifest, KernelSpec};
pub use native::NativeBackend;
pub use pooled::{corr_tile_pooled, pcit_tile_pooled};

use crate::util::{Matrix, MatrixView};
use std::sync::Arc;

/// Executes the two PCIT tile shapes plus the generic similarity tile.
/// Implementations must be `Send + Sync`: one executor is shared by all
/// worker threads (PJRT executables are internally synchronized).
///
/// Operands are borrowed [`MatrixView`]s so quorum tiles read straight out
/// of the rank's standardized matrix — no per-tile operand copies. The
/// native backend computes in place; the XLA backend copies once at its
/// channel boundary (PJRT literals need owned buffers anyway).
pub trait TileExecutor: Send + Sync {
    /// Correlation tile between standardized row blocks:
    /// `za` (A×M) · `zb` (B×M)ᵀ, clamped to [-1, 1]. A, B, M arbitrary.
    fn corr_tile(&self, za: MatrixView<'_>, zb: MatrixView<'_>) -> Matrix;

    /// PCIT elimination tile: OR over mediators z of
    /// `trio_eliminates(cxy[x,y], rxz[x,z], ryz[y,z])`.
    /// `cxy`: A×B, `rxz`: A×Z, `ryz`: B×Z → A×B flags as f32 (0.0 / 1.0).
    fn pcit_tile(&self, cxy: MatrixView<'_>, rxz: MatrixView<'_>, ryz: MatrixView<'_>) -> Matrix;

    /// Human-readable backend name (reports, benches).
    fn name(&self) -> &'static str;
}

/// Shared executor handle.
pub type Executor = Arc<dyn TileExecutor>;

/// Build an executor from a config backend kind.
pub fn executor_for(
    kind: crate::config::BackendKind,
    artifacts_dir: &std::path::Path,
) -> anyhow::Result<Executor> {
    match kind {
        crate::config::BackendKind::Native => Ok(Arc::new(native::NativeBackend::new())),
        crate::config::BackendKind::Xla => {
            let e = engine::XlaBackend::load(artifacts_dir)?;
            Ok(Arc::new(e))
        }
    }
}

/// Convert an elimination flag matrix (0.0/1.0) to a boolean mask.
pub fn flags_to_mask(flags: &Matrix) -> Vec<bool> {
    flags.as_slice().iter().map(|&v| v > 0.5).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_convert() {
        let m = Matrix::from_vec(1, 3, vec![0.0, 1.0, 0.0]);
        assert_eq!(flags_to_mask(&m), vec![false, true, false]);
    }
}
