//! PJRT-backed tile executor: loads HLO-text artifacts, compiles them on
//! the `xla` crate's CPU client, and executes tiles with zero-padding to
//! the artifacts' static shapes.
//!
//! The whole backend is gated behind the off-by-default `xla` cargo feature
//! (the `xla` crate and its PJRT closure are not available in the offline
//! build environment); without it, [`XlaBackend::load`] is a stub that
//! returns a descriptive error, so `BackendKind::Xla` fails cleanly at
//! executor construction and every other code path is unaffected.
//!
//! The `xla` crate's PJRT handles are neither `Send` nor `Sync` (raw
//! pointers + `Rc` client), so a dedicated **service thread** owns the
//! client and executables; [`XlaBackend`] is a `Send + Sync` facade that
//! ships tile requests over a channel and blocks on the response. Tile
//! operands arrive as borrowed [`MatrixView`]s and are materialized exactly
//! once at this channel boundary (PJRT literals need owned buffers anyway);
//! PJRT CPU execution is internally multi-threaded, so a single submission
//! queue costs little (measured in EXPERIMENTS.md §Perf).
//!
//! Padding is semantically safe by construction:
//! * `corr_chunk` — zero rows/columns contribute 0 to every dot product;
//!   extra output rows/cols are sliced away. M is accumulated in chunks
//!   *before* clamping, so splitting M is exact.
//! * `pcit_chunk` — a zero mediator column has `|r| < EPS_GUARD`, which
//!   `trio_eliminates` rejects, so padded z never eliminates; padded rows
//!   are sliced away.

#[cfg(feature = "xla")]
pub use real::XlaBackend;

#[cfg(not(feature = "xla"))]
pub use stub::XlaBackend;

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::runtime::TileExecutor;
    use crate::util::{Matrix, MatrixView};
    use anyhow::Result;
    use std::path::Path;

    /// Placeholder compiled without the `xla` feature: construction always
    /// fails, so the tile methods are unreachable by design.
    pub struct XlaBackend {
        _unconstructible: (),
    }

    impl XlaBackend {
        pub fn load(_dir: &Path) -> Result<Self> {
            anyhow::bail!(
                "this build does not include the XLA/PJRT backend — \
                 rebuild with `--features xla` (requires the `xla` crate)"
            )
        }
    }

    impl TileExecutor for XlaBackend {
        fn corr_tile(&self, _za: MatrixView<'_>, _zb: MatrixView<'_>) -> Matrix {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        fn pcit_tile(&self, _cxy: MatrixView<'_>, _rxz: MatrixView<'_>, _ryz: MatrixView<'_>) -> Matrix {
            unreachable!("stub XlaBackend cannot be constructed")
        }

        fn name(&self) -> &'static str {
            "xla-stub"
        }
    }
}

#[cfg(feature = "xla")]
mod real {
    use crate::runtime::artifact::ArtifactManifest;
    use crate::runtime::TileExecutor;
    use crate::util::{Matrix, MatrixView};
    use anyhow::{Context, Result};
    use std::path::{Path, PathBuf};
    use std::sync::mpsc::{channel, Sender};
    use std::sync::Mutex;

    enum Req {
        Corr { za: Matrix, zb: Matrix, resp: Sender<Result<Matrix>> },
        Pcit { cxy: Matrix, rxz: Matrix, ryz: Matrix, resp: Sender<Result<Matrix>> },
        Shutdown,
    }

    /// `Send + Sync` facade over the XLA service thread.
    pub struct XlaBackend {
        tx: Mutex<Sender<Req>>,
        handle: Option<std::thread::JoinHandle<()>>,
    }

    impl XlaBackend {
        /// Load and compile all kernels from `artifacts/` on the service thread.
        pub fn load(dir: &Path) -> Result<Self> {
            let dir = dir.to_path_buf();
            let (tx, rx) = channel::<Req>();
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let handle = std::thread::Builder::new()
                .name("quorall-xla".into())
                .spawn(move || service_main(dir, rx, ready_tx))
                .context("spawning XLA service thread")?;
            ready_rx
                .recv()
                .context("XLA service thread died during startup")??;
            Ok(Self { tx: Mutex::new(tx), handle: Some(handle) })
        }

        fn request(&self, build: impl FnOnce(Sender<Result<Matrix>>) -> Req) -> Result<Matrix> {
            let (rtx, rrx) = channel();
            {
                let tx = self.tx.lock().unwrap();
                tx.send(build(rtx)).map_err(|_| anyhow::anyhow!("XLA service thread gone"))?;
            }
            rrx.recv().context("XLA service dropped the request")?
        }
    }

    impl Drop for XlaBackend {
        fn drop(&mut self) {
            if let Ok(tx) = self.tx.lock() {
                let _ = tx.send(Req::Shutdown);
            }
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }

    impl TileExecutor for XlaBackend {
        fn corr_tile(&self, za: MatrixView<'_>, zb: MatrixView<'_>) -> Matrix {
            // Views are materialized once here, at the channel boundary.
            self.request(|resp| Req::Corr { za: za.to_matrix(), zb: zb.to_matrix(), resp })
                .expect("XLA corr tile execution failed")
        }

        fn pcit_tile(&self, cxy: MatrixView<'_>, rxz: MatrixView<'_>, ryz: MatrixView<'_>) -> Matrix {
            self.request(|resp| Req::Pcit {
                cxy: cxy.to_matrix(),
                rxz: rxz.to_matrix(),
                ryz: ryz.to_matrix(),
                resp,
            })
            .expect("XLA pcit tile execution failed")
        }

        fn name(&self) -> &'static str {
            "xla"
        }
    }

    // ---------------- service thread ----------------

    struct Compiled {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Compiled {
        fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Self { exe })
        }

        /// Execute with f32 matrix inputs; result = first tuple element.
        fn run(&self, inputs: &[&Matrix], out_rows: usize, out_cols: usize) -> Result<Matrix> {
            let mut lits = Vec::with_capacity(inputs.len());
            for m in inputs {
                let lit = xla::Literal::vec1(m.as_slice())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .context("reshaping input literal")?;
                lits.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
            let out = result.to_tuple1().context("unwrapping result tuple")?;
            let values = out.to_vec::<f32>().context("reading result values")?;
            anyhow::ensure!(
                values.len() == out_rows * out_cols,
                "result size {} != {}x{}",
                values.len(),
                out_rows,
                out_cols
            );
            Ok(Matrix::from_vec(out_rows, out_cols, values))
        }
    }

    struct Service {
        corr: Compiled,
        pcit: Compiled,
        corr_a: usize,
        corr_b: usize,
        corr_m: usize,
        pcit_a: usize,
        pcit_b: usize,
        pcit_z: usize,
    }

    fn service_main(dir: PathBuf, rx: std::sync::mpsc::Receiver<Req>, ready: Sender<Result<()>>) {
        let svc = match Service::load(&dir) {
            Ok(s) => {
                let _ = ready.send(Ok(()));
                s
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        while let Ok(req) = rx.recv() {
            match req {
                Req::Corr { za, zb, resp } => {
                    let _ = resp.send(svc.corr_tile(&za, &zb));
                }
                Req::Pcit { cxy, rxz, ryz, resp } => {
                    let _ = resp.send(svc.pcit_tile(&cxy, &rxz, &ryz));
                }
                Req::Shutdown => break,
            }
        }
    }

    impl Service {
        fn load(dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(dir)?;
            manifest.verify_shapes()?; // catches stale artifacts pre-compile
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let ck = manifest.kernel("corr_chunk")?;
            let pk = manifest.kernel("pcit_chunk")?;
            Ok(Self {
                corr: Compiled::load(&client, &ck.file)?,
                pcit: Compiled::load(&client, &pk.file)?,
                corr_a: ck.dim("a")?,
                corr_b: ck.dim("b")?,
                corr_m: ck.dim("m")?,
                pcit_a: pk.dim("a")?,
                pcit_b: pk.dim("b")?,
                pcit_z: pk.dim("z")?,
            })
        }

        fn corr_tile(&self, za: &Matrix, zb: &Matrix) -> Result<Matrix> {
            let (a, m) = za.shape();
            let (b, m2) = zb.shape();
            anyhow::ensure!(m == m2, "sample dimension mismatch");
            // Large row blocks are tiled over the artifact's static (a, b) shape.
            if a > self.corr_a || b > self.corr_b {
                let mut out = Matrix::zeros(a, b);
                let mut r0 = 0usize;
                while r0 < a {
                    let rh = self.corr_a.min(a - r0);
                    let za_t = za.block(r0, 0, rh, m);
                    let mut c0 = 0usize;
                    while c0 < b {
                        let cw = self.corr_b.min(b - c0);
                        let zb_t = zb.block(c0, 0, cw, m);
                        let tile = self.corr_tile(&za_t, &zb_t)?;
                        out.set_block(r0, c0, &tile);
                        c0 += cw;
                    }
                    r0 += rh;
                }
                return Ok(out);
            }
            let mut acc = Matrix::zeros(self.corr_a, self.corr_b);
            let mut m0 = 0usize;
            while m0 < m {
                let w = self.corr_m.min(m - m0);
                let za_c = pad_to(&za.block(0, m0, a, w), self.corr_a, self.corr_m);
                let zb_c = pad_to(&zb.block(0, m0, b, w), self.corr_b, self.corr_m);
                let part = self.corr.run(&[&za_c, &zb_c], self.corr_a, self.corr_b)?;
                for (o, v) in acc.as_mut_slice().iter_mut().zip(part.as_slice()) {
                    *o += v;
                }
                m0 += w;
            }
            for v in acc.as_mut_slice() {
                *v = v.clamp(-1.0, 1.0);
            }
            Ok(acc.block(0, 0, a, b))
        }

        fn pcit_tile(&self, cxy: &Matrix, rxz: &Matrix, ryz: &Matrix) -> Result<Matrix> {
            let (a, b) = cxy.shape();
            let z = rxz.cols();
            anyhow::ensure!(rxz.rows() == a && ryz.rows() == b && ryz.cols() == z, "shape mismatch");
            // Tile large pair blocks over the static (a, b) shape.
            if a > self.pcit_a || b > self.pcit_b {
                let mut out = Matrix::zeros(a, b);
                let mut r0 = 0usize;
                while r0 < a {
                    let rh = self.pcit_a.min(a - r0);
                    let rxz_t = rxz.block(r0, 0, rh, z);
                    let mut c0 = 0usize;
                    while c0 < b {
                        let cw = self.pcit_b.min(b - c0);
                        let cxy_t = cxy.block(r0, c0, rh, cw);
                        let ryz_t = ryz.block(c0, 0, cw, z);
                        let tile = self.pcit_tile(&cxy_t, &rxz_t, &ryz_t)?;
                        out.set_block(r0, c0, &tile);
                        c0 += cw;
                    }
                    r0 += rh;
                }
                return Ok(out);
            }
            let cxy_p = pad_to(cxy, self.pcit_a, self.pcit_b);
            let mut flags = Matrix::zeros(self.pcit_a, self.pcit_b);
            let mut z0 = 0usize;
            while z0 < z {
                let w = self.pcit_z.min(z - z0);
                let rxz_c = pad_to(&rxz.block(0, z0, a, w), self.pcit_a, self.pcit_z);
                let ryz_c = pad_to(&ryz.block(0, z0, b, w), self.pcit_b, self.pcit_z);
                let part = self.pcit.run(&[&cxy_p, &rxz_c, &ryz_c], self.pcit_a, self.pcit_b)?;
                for (o, v) in flags.as_mut_slice().iter_mut().zip(part.as_slice()) {
                    *o = if *o > 0.5 || *v > 0.5 { 1.0 } else { 0.0 };
                }
                z0 += w;
            }
            Ok(flags.block(0, 0, a, b))
        }
    }

    /// Zero-pad `m` to (rows, cols).
    fn pad_to(m: &Matrix, rows: usize, cols: usize) -> Matrix {
        if m.shape() == (rows, cols) {
            m.clone()
        } else {
            m.padded(rows, cols, 0.0)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn pad_preserves_content() {
            let m = Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
            let p = pad_to(&m, 4, 5);
            assert_eq!(p.shape(), (4, 5));
            assert_eq!(p[(1, 2)], 5.0);
            assert_eq!(p[(3, 4)], 0.0);
            assert_eq!(pad_to(&m, 2, 3), m);
        }

        // XLA-loading tests live in rust/tests/integration_runtime.rs — they
        // require `make artifacts` to have produced the HLO files.
    }
}

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;

    #[test]
    fn stub_load_errors_cleanly() {
        let err = XlaBackend::load(std::path::Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("xla"), "unexpected: {err}");
    }
}
