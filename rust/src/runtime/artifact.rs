//! Artifact manifest: metadata for the AOT-compiled HLO programs.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing each
//! kernel's file and static shapes. HLO **text** is the interchange format
//! (see DESIGN.md §2 — jax ≥ 0.5 serialized protos are rejected by
//! xla_extension 0.5.1).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Static shape info for one kernel artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelSpec {
    pub name: String,
    pub file: PathBuf,
    /// Named integer dimensions, e.g. {"a": 128, "b": 128, "m": 128}.
    pub dims: BTreeMap<String, usize>,
}

impl KernelSpec {
    pub fn dim(&self, name: &str) -> Result<usize> {
        self.dims
            .get(name)
            .copied()
            .with_context(|| format!("kernel '{}' missing dim '{name}'", self.name))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub version: usize,
    pub kernels: BTreeMap<String, KernelSpec>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` to AOT-compile the kernels",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let j = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = j.get("version").and_then(|v| v.as_usize()).unwrap_or(1);
        let Some(kernels_obj) = j.get("kernels").and_then(|k| k.as_obj()) else {
            bail!("manifest missing 'kernels' object");
        };
        let mut kernels = BTreeMap::new();
        for (name, spec) in kernels_obj {
            let Some(file) = spec.get("file").and_then(|f| f.as_str()) else {
                bail!("kernel '{name}' missing 'file'");
            };
            let mut dims = BTreeMap::new();
            if let Some(obj) = spec.as_obj() {
                for (k, v) in obj {
                    if k == "file" {
                        continue;
                    }
                    if let Some(n) = v.as_usize() {
                        dims.insert(k.clone(), n);
                    }
                }
            }
            kernels.insert(
                name.clone(),
                KernelSpec { name: name.clone(), file: dir.join(file), dims },
            );
        }
        Ok(Self { version, kernels, dir: dir.to_path_buf() })
    }

    pub fn kernel(&self, name: &str) -> Result<&KernelSpec> {
        self.kernels
            .get(name)
            .with_context(|| format!("manifest has no kernel '{name}' (have: {:?})", self.kernels.keys().collect::<Vec<_>>()))
    }

    /// All artifact files exist on disk?
    pub fn verify_files(&self) -> Result<()> {
        for k in self.kernels.values() {
            if !k.file.exists() {
                bail!("artifact file missing: {} (run `make artifacts`)", k.file.display());
            }
        }
        Ok(())
    }

    /// Deep check: every artifact parses as HLO text and its parameter
    /// shapes are consistent with the manifest dims. Catches stale
    /// artifacts after a kernel-shape change without a `make artifacts`.
    pub fn verify_shapes(&self) -> Result<()> {
        self.verify_files()?;
        for k in self.kernels.values() {
            let text = std::fs::read_to_string(&k.file)
                .with_context(|| format!("reading {}", k.file.display()))?;
            let info = HloInfo::parse(&text)
                .with_context(|| format!("parsing {}", k.file.display()))?;
            for dim in k.dims.values() {
                anyhow::ensure!(
                    info.mentions_dim(*dim),
                    "artifact {} does not mention manifest dim {} — stale artifacts? run `make artifacts`",
                    k.file.display(),
                    dim
                );
            }
        }
        Ok(())
    }
}

/// Lightweight structural view of an HLO text module (header + parameter
/// shapes) — enough to sanity-check artifacts without an XLA client.
#[derive(Clone, Debug, PartialEq)]
pub struct HloInfo {
    pub module_name: String,
    /// All `f32[a,b]`-style shapes appearing in the ENTRY signature.
    pub entry_shapes: Vec<Vec<usize>>,
}

impl HloInfo {
    pub fn parse(text: &str) -> Result<HloInfo> {
        let first = text.lines().next().unwrap_or("");
        anyhow::ensure!(first.starts_with("HloModule"), "not HLO text (missing HloModule header)");
        let module_name = first
            .split_whitespace()
            .nth(1)
            .unwrap_or("?")
            .trim_end_matches(',')
            .to_string();
        // Entry parameter/result shapes live in the header's
        // `entry_computation_layout={(f32[a,b]{...}, ...) -> ...}`; older
        // emitters put them on the ENTRY line instead — harvest both.
        let mut entry_shapes = Vec::new();
        let entry_line = text.lines().find(|l| l.trim_start().starts_with("ENTRY"));
        for line in [Some(first), entry_line].into_iter().flatten() {
            let mut i = 0usize;
            while let Some(pos) = line[i..].find("f32[") {
                let start = i + pos + 4;
                let Some(end_rel) = line[start..].find(']') else { break };
                let dims_str = &line[start..start + end_rel];
                let dims: Vec<usize> = dims_str
                    .split(',')
                    .filter_map(|d| d.trim().parse().ok())
                    .collect();
                if !dims.is_empty() {
                    entry_shapes.push(dims);
                }
                i = start + end_rel + 1;
                if i >= line.len() {
                    break;
                }
            }
        }
        anyhow::ensure!(
            !entry_shapes.is_empty(),
            "no f32 array shapes found in HLO header/ENTRY"
        );
        Ok(HloInfo { module_name, entry_shapes })
    }

    /// Does some entry shape contain this dimension?
    pub fn mentions_dim(&self, dim: usize) -> bool {
        self.entry_shapes.iter().any(|s| s.contains(&dim))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "kernels": {
            "corr_chunk": {"file": "corr.hlo.txt", "a": 128, "b": 128, "m": 128},
            "pcit_chunk": {"file": "pcit.hlo.txt", "a": 128, "b": 128, "z": 128}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.version, 1);
        let k = m.kernel("corr_chunk").unwrap();
        assert_eq!(k.dim("m").unwrap(), 128);
        assert_eq!(k.file, PathBuf::from("/tmp/a/corr.hlo.txt"));
        assert!(m.kernel("nope").is_err());
        assert!(k.dim("zz").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("{}", Path::new(".")).is_err());
        assert!(ArtifactManifest::parse(r#"{"kernels": {"x": {}}}"#, Path::new(".")).is_err());
        assert!(ArtifactManifest::parse("not json", Path::new(".")).is_err());
    }

    #[test]
    fn verify_files_reports_missing() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/nonexistent-dir")).unwrap();
        assert!(m.verify_files().is_err());
    }

    const SAMPLE_HLO: &str = "HloModule jit_corr_entry, entry_computation_layout={(f32[128,128]{1,0}, f32[128,128]{1,0})->(f32[128,128]{1,0})}\n\nENTRY main.5 (Arg_0.1: f32[128,128], Arg_1.2: f32[128,128]) -> (f32[128,128]) {\n}\n";

    #[test]
    fn hlo_info_parses_entry_shapes() {
        let info = HloInfo::parse(SAMPLE_HLO).unwrap();
        assert_eq!(info.module_name, "jit_corr_entry");
        assert!(info.entry_shapes.contains(&vec![128, 128]));
        assert!(info.mentions_dim(128));
        assert!(!info.mentions_dim(64));
    }

    #[test]
    fn hlo_info_rejects_non_hlo() {
        assert!(HloInfo::parse("not hlo at all").is_err());
        assert!(HloInfo::parse("HloModule x\n(no entry)\n").is_err());
    }

    #[test]
    fn verify_shapes_on_real_artifacts_if_present() {
        // Runs the deep check whenever `make artifacts` has been executed.
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(dir).unwrap();
            m.verify_shapes().unwrap();
        }
    }
}
