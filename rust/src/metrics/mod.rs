//! Metrics: logical memory accounting per rank (Figure 2 right), real RSS,
//! communication counters, and report tables.

pub mod memory;
pub mod report;

pub use memory::{peak_rss_bytes, MemoryAccountant, MemorySnapshot};
pub use report::Table;

use std::sync::atomic::{AtomicU64, Ordering};

/// Cross-thread communication counters (owned by the transport).
#[derive(Debug, Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl CommStats {
    pub fn record(&self, bytes: u64) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_stats_accumulate() {
        let s = CommStats::default();
        s.record(100);
        s.record(50);
        assert_eq!(s.snapshot(), (2, 150));
    }
}
