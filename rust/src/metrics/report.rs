//! Plain-text table formatting for bench output and CLI reports.

use crate::util::json::Json;

/// A simple aligned table: header + rows of strings.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Machine-readable form: `{title, rows: [{header_i: cell_i, ...}]}` —
    /// the payload `benchkit::write_json` persists as `BENCH_*.json`.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let mut obj = std::collections::BTreeMap::new();
                for (h, c) in self.header.iter().zip(row) {
                    // Numbers stay numbers so downstream tooling can plot.
                    let v = match c.parse::<f64>() {
                        Ok(x) if x.is_finite() => Json::Num(x),
                        _ => Json::Str(c.clone()),
                    };
                    obj.insert(h.clone(), v);
                }
                Json::Obj(obj)
            })
            .collect();
        let mut top = std::collections::BTreeMap::new();
        top.insert("title".to_string(), Json::Str(self.title.clone()));
        top.insert("rows".to_string(), Json::Arr(rows));
        Json::Obj(top)
    }

    /// Render as CSV (for EXPERIMENTS.md ingestion).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("alpha"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_round() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn json_keeps_numbers() {
        let mut t = Table::new("bench", &["name", "gflops"]);
        t.row(vec!["seed".into(), "1.25".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").and_then(|v| v.as_str()), Some("bench"));
        let rows = j.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows[0].get("gflops").and_then(|v| v.as_f64()), Some(1.25));
        assert_eq!(rows[0].get("name").and_then(|v| v.as_str()), Some("seed"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
