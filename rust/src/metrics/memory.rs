//! Memory accounting.
//!
//! Two complementary views, both reported in Figure 2-R:
//! * **Logical bytes per rank** — the [`MemoryAccountant`] sums the data a
//!   rank actually holds (input blocks, correlation tiles, row blocks, ring
//!   buffers). This is the quantity the paper's claim is about and is
//!   independent of allocator noise.
//! * **Peak RSS** of the whole process via `getrusage(2)` — a sanity bound.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Tracks current and peak logical bytes for one rank. Cheap, thread-safe.
#[derive(Debug, Default)]
pub struct MemoryAccountant {
    current: AtomicU64,
    peak: AtomicU64,
}

impl MemoryAccountant {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record an allocation of `bytes`.
    pub fn alloc(&self, bytes: u64) {
        let cur = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(cur, Ordering::Relaxed);
    }

    /// Record a release of `bytes`.
    pub fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes.min(self.current.load(Ordering::Relaxed)), Ordering::Relaxed);
    }

    pub fn current_bytes(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MemorySnapshot {
        MemorySnapshot { current: self.current_bytes(), peak: self.peak_bytes() }
    }
}

/// Point-in-time view of a rank's memory.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemorySnapshot {
    pub current: u64,
    pub peak: u64,
}

/// Whole-process peak resident set size in bytes (Linux: ru_maxrss is KiB).
pub fn peak_rss_bytes() -> u64 {
    use crate::util::sys;
    let mut ru = sys::rusage::default();
    // SAFETY: plain FFI call writing into a stack-owned struct whose
    // declaration covers the full kernel layout (`util::sys`).
    let r = unsafe { sys::getrusage(sys::RUSAGE_SELF, &mut ru) };
    if r == 0 {
        (ru.ru_maxrss as u64) * 1024
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let a = MemoryAccountant::default();
        a.alloc(100);
        a.alloc(200);
        a.free(250);
        a.alloc(10);
        assert_eq!(a.current_bytes(), 60);
        assert_eq!(a.peak_bytes(), 300);
    }

    #[test]
    fn free_saturates() {
        let a = MemoryAccountant::default();
        a.alloc(10);
        a.free(1000);
        assert!(a.current_bytes() <= 10);
    }

    #[test]
    fn rss_is_positive() {
        let rss = peak_rss_bytes();
        assert!(rss > 1024 * 1024, "peak RSS should exceed 1 MiB, got {rss}");
    }

    #[test]
    fn concurrent_updates() {
        let a = MemoryAccountant::new();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = Arc::clone(&a);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    a.alloc(3);
                    a.free(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.current_bytes(), 4 * 1000 * 2);
        assert!(a.peak_bytes() >= a.current_bytes());
    }
}
