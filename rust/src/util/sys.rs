//! Minimal libc FFI surface (Linux) — the offline build vendors no `libc`
//! crate, so the two syscalls the metrics layer needs are declared here
//! directly. Layouts match the x86_64/aarch64 Linux ABI (`tv_sec`/`tv_nsec`
//! and every `rusage` counter are C `long`, i.e. 64-bit on LP64).

#![allow(non_camel_case_types)]

/// `CLOCK_THREAD_CPUTIME_ID` from `<time.h>` (Linux).
pub const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
/// `RUSAGE_SELF` from `<sys/resource.h>`.
pub const RUSAGE_SELF: i32 = 0;

#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct timespec {
    pub tv_sec: i64,
    pub tv_nsec: i64,
}

#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct timeval {
    pub tv_sec: i64,
    pub tv_usec: i64,
}

/// Full Linux `struct rusage`: the kernel writes every field, so the
/// declaration must cover all of them even though only `ru_maxrss` is read.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct rusage {
    pub ru_utime: timeval,
    pub ru_stime: timeval,
    pub ru_maxrss: i64,
    pub ru_ixrss: i64,
    pub ru_idrss: i64,
    pub ru_isrss: i64,
    pub ru_minflt: i64,
    pub ru_majflt: i64,
    pub ru_nswap: i64,
    pub ru_inblock: i64,
    pub ru_oublock: i64,
    pub ru_msgsnd: i64,
    pub ru_msgrcv: i64,
    pub ru_nsignals: i64,
    pub ru_nvcsw: i64,
    pub ru_nivcsw: i64,
}

extern "C" {
    pub fn clock_gettime(clockid: i32, tp: *mut timespec) -> i32;
    pub fn getrusage(who: i32, usage: *mut rusage) -> i32;
}
