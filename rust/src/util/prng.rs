//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256**`, the standard pairing. Deterministic
//! seeding is load-bearing: synthetic datasets, property tests and the
//! randomized difference-set search must all be reproducible from a seed
//! recorded in EXPERIMENTS.md.

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main PRNG used across the crate.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // Avoid the all-zero state (period-breaking).
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0) is meaningless");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached second variate omitted for
    /// simplicity; callers in hot loops should batch).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(13);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
