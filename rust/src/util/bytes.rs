//! Human-readable byte formatting for memory reports.

/// Format a byte count with binary units (KiB/MiB/GiB), 2 decimals.
pub fn format_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if bytes < 1024 {
        return format!("{bytes} B");
    }
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Parse strings like "64KiB", "1.5 MiB", "2GB" (decimal SI accepted too).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| c.is_ascii_alphabetic() || c == ' ');
    let (num, unit) = match split {
        Some(i) => (s[..i].trim(), s[i..].trim()),
        None => (s, ""),
    };
    let v: f64 = num.parse().ok()?;
    let mult: f64 = match unit.to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" => 1e3,
        "kib" => 1024.0,
        "m" | "mb" => 1e6,
        "mib" => 1024.0 * 1024.0,
        "g" | "gb" => 1e9,
        "gib" => 1024.0 * 1024.0 * 1024.0,
        "t" | "tb" => 1e12,
        "tib" => 1024.0f64.powi(4),
        _ => return None,
    };
    Some((v * mult) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_round_trip_ish() {
        assert_eq!(format_bytes(0), "0 B");
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(1024), "1.00 KiB");
        assert_eq!(format_bytes(1536), "1.50 KiB");
        assert_eq!(format_bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(format_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_bytes("1024"), Some(1024));
        assert_eq!(parse_bytes("1 KiB"), Some(1024));
        assert_eq!(parse_bytes("1.5MiB"), Some(1572864));
        assert_eq!(parse_bytes("2GB"), Some(2_000_000_000));
        assert_eq!(parse_bytes("nonsense"), None);
    }
}
