//! Minimal JSON parser + writer.
//!
//! Used for `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and for machine-readable bench/experiment output. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP are passed through
//! unvalidated.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.keyword("null", Json::Null),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience builder for object literals in Rust code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("line\nquote\"backslash\\tab\t".into());
        let s = original.to_string();
        assert_eq!(Json::parse(&s).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str(), Some("é"));
    }

    #[test]
    fn round_trip_pretty() {
        let j = Json::parse(r#"{"x": [1, 2.5, true], "y": {"z": "w"}}"#).unwrap();
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 7, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
