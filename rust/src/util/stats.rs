//! Summary statistics used by the bench harness and the paper-figure
//! reproductions (mean, variance, 95 % confidence interval — Figure 2 in the
//! paper reports 95 % CI error bars over up to 20 runs).

/// Online/batch summary of a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub m2: f64, // sum of squared deviations (Welford)
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    /// Welford's online update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Half-width of the 95 % confidence interval on the mean, using the
    /// Student-t critical value for small n (matches the paper's error bars).
    pub fn ci95_half_width(&self) -> f64 {
        t_crit_95(self.n.saturating_sub(1)) * self.sem()
    }
}

/// Two-sided 95 % Student-t critical values by degrees of freedom. Exact
/// table for df <= 30, asymptote 1.96 beyond.
pub fn t_crit_95(df: usize) -> f64 {
    const TABLE: [f64; 31] = [
        f64::INFINITY, // df = 0 (undefined; single observation)
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return 0.0; // a single sample has no CI; report zero width
    }
    if df < TABLE.len() {
        TABLE[df]
    } else {
        1.96
    }
}

/// Median of a sample (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

/// Percentile in [0,100] using nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Pearson correlation between two equal-length f64 slices (used in tests to
/// cross-check the f32 production path).
pub fn pearson_f64(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx.sqrt() * syy.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_single_point() {
        let s = Summary::from_slice(&[7.0]);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.ci95_half_width(), 0.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_slice(&[1.0, 2.0, 3.0]);
        let many: Vec<f64> = (0..300).map(|i| 1.0 + (i % 3) as f64).collect();
        let b = Summary::from_slice(&many);
        assert!(b.ci95_half_width() < a.ci95_half_width());
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }

    #[test]
    fn pearson_perfect() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        assert!((pearson_f64(&x, &y) - 1.0).abs() < 1e-12);
        let z: Vec<f64> = x.iter().map(|v| -2.0 * v).collect();
        assert!((pearson_f64(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        let x = vec![1.0; 10];
        let y: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(pearson_f64(&x, &y), 0.0);
    }
}
