//! Dense row-major `f32` matrix — the core numeric container — plus
//! borrowed [`MatrixView`]/[`MatrixViewMut`] windows over it.
//!
//! Gene-expression inputs are `N×M` (genes × samples); correlation blocks are
//! `B×B`. Row-major layout matches both the XLA literal layout used by the
//! runtime bridge and the cache-friendly row iteration of the native kernels.
//!
//! The all-pairs hot path reads quorum tiles *in place* through views
//! (row offset + stride) instead of copying operand blocks, and the shared
//! `matmul_nt` kernel is register-tiled and cache-panelled
//! (EXPERIMENTS.md §Perf). Every kernel keeps each output element's
//! k-accumulation in strict ascending order, so blocked, pooled, seed and
//! naive variants are all **bitwise identical** — the invariant that keeps
//! distributed and single-node results exactly equal.

use crate::pool::ThreadPool;
use std::fmt;
use std::ops::{Index, IndexMut, Range};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

/// Borrowed read-only window into a row-major buffer: `rows × cols`
/// elements where consecutive rows are `stride` elements apart. Copyable
/// and cheap — the zero-copy currency of the tile hot path.
#[derive(Clone, Copy)]
pub struct MatrixView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
    stride: usize,
}

/// Borrowed mutable window (same layout rules as [`MatrixView`]).
pub struct MatrixViewMut<'a> {
    data: &'a mut [f32],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// From an existing row-major buffer (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Logical size in bytes of the backing buffer.
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Zero-copy view of the whole matrix.
    #[inline]
    pub fn view(&self) -> MatrixView<'_> {
        MatrixView { data: &self.data, rows: self.rows, cols: self.cols, stride: self.cols }
    }

    /// Zero-copy view of the sub-block `[r0..r0+h) × [c0..c0+w)`.
    #[inline]
    pub fn view_block(&self, r0: usize, c0: usize, h: usize, w: usize) -> MatrixView<'_> {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        if h == 0 || w == 0 {
            return MatrixView { data: &[], rows: h, cols: w, stride: 0 };
        }
        // Span from the block's first element to the end of its last row.
        let start = r0 * self.cols + c0;
        let end = start + (h - 1) * self.cols + w;
        MatrixView { data: &self.data[start..end], rows: h, cols: w, stride: self.cols }
    }

    /// Zero-copy view of a contiguous row range (full width).
    #[inline]
    pub fn view_rows(&self, r: Range<usize>) -> MatrixView<'_> {
        self.view_block(r.start, 0, r.len(), self.cols)
    }

    /// Mutable zero-copy view of the whole matrix.
    #[inline]
    pub fn view_mut(&mut self) -> MatrixViewMut<'_> {
        MatrixViewMut { rows: self.rows, cols: self.cols, stride: self.cols, data: &mut self.data }
    }

    /// Copy a sub-block `[r0..r0+h) × [c0..c0+w)` into a new matrix.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        self.view_block(r0, c0, h, w).to_matrix()
    }

    /// Write a block into this matrix at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Matrix) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols, "block out of range");
        for r in 0..b.rows {
            let dst = r0 + r;
            self.data[dst * self.cols + c0..dst * self.cols + c0 + b.cols]
                .copy_from_slice(b.row(r));
        }
    }

    /// Write `b`'s **transpose** into this matrix at `(r0, c0)` — the
    /// symmetric-assembly primitive: `self[r0+j][c0+i] = b[i][j]` — without
    /// materializing a transposed copy of `b`. Processed in 32×32 tiles so
    /// one side of the scatter always walks contiguous memory.
    pub fn set_block_transposed(&mut self, r0: usize, c0: usize, b: &Matrix) {
        self.set_block_transposed_view(r0, c0, b.view());
    }

    /// View-operand variant of [`Matrix::set_block_transposed`].
    pub fn set_block_transposed_view(&mut self, r0: usize, c0: usize, b: MatrixView<'_>) {
        let (bh, bw) = b.shape();
        assert!(r0 + bw <= self.rows && c0 + bh <= self.cols, "block out of range");
        const TB: usize = 32;
        let cols = self.cols;
        let mut rb = 0;
        while rb < bh {
            let rh = TB.min(bh - rb);
            let mut cb = 0;
            while cb < bw {
                let cw = TB.min(bw - cb);
                for i in rb..rb + rh {
                    let src = &b.row(i)[cb..cb + cw];
                    for (jj, &v) in src.iter().enumerate() {
                        let j = cb + jj;
                        self.data[(r0 + j) * cols + c0 + i] = v;
                    }
                }
                cb += cw;
            }
            rb += rh;
        }
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (i, &c) in idx.iter().enumerate() {
                dst[i] = src[c];
            }
        }
        out
    }

    /// Transposed copy, processed in 32×32 tiles so the column-stride walk
    /// of the destination stays inside one cache-line working set per tile.
    pub fn transpose(&self) -> Matrix {
        const TB: usize = 32;
        let (n, m) = (self.rows, self.cols);
        let mut out = Matrix::zeros(m, n);
        let mut rb = 0;
        while rb < n {
            let rh = TB.min(n - rb);
            let mut cb = 0;
            while cb < m {
                let cw = TB.min(m - cb);
                for r in rb..rb + rh {
                    let src = &self.data[r * m + cb..r * m + cb + cw];
                    for (cc, &v) in src.iter().enumerate() {
                        out.data[(cb + cc) * n + r] = v;
                    }
                }
                cb += cw;
            }
            rb += rh;
        }
        out
    }

    /// Plain `self · otherᵀ` (used for standardized-row correlation:
    /// rows of both operands are observations over the same M columns).
    /// Register-tiled and cache-panelled; see [`matmul_nt_into`].
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows);
        matmul_nt_into(self.view(), other.view(), &mut out.view_mut());
        out
    }

    /// The seed repo's 4-wide-ILP kernel, kept verbatim for differential
    /// tests and the `kernel_tiles` speedup baseline. Bitwise identical to
    /// both [`matmul_nt_reference`] and the blocked [`Matrix::matmul_nt`].
    #[doc(hidden)]
    pub fn matmul_nt_seed(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "inner dimension mismatch");
        let (n, m, k) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(n, m);
        let bdat = &other.data;
        for i in 0..n {
            let a = self.row(i);
            let orow = &mut out.data[i * m..(i + 1) * m];
            let mut j = 0usize;
            while j + 4 <= m {
                let b0 = &bdat[j * k..(j + 1) * k];
                let b1 = &bdat[(j + 1) * k..(j + 2) * k];
                let b2 = &bdat[(j + 2) * k..(j + 3) * k];
                let b3 = &bdat[(j + 3) * k..(j + 4) * k];
                let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for l in 0..k {
                    let av = a[l];
                    c0 += av * b0[l];
                    c1 += av * b1[l];
                    c2 += av * b2[l];
                    c3 += av * b3[l];
                }
                orow[j] = c0;
                orow[j + 1] = c1;
                orow[j + 2] = c2;
                orow[j + 3] = c3;
                j += 4;
            }
            while j < m {
                let b = &bdat[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for l in 0..k {
                    acc += a[l] * b[l];
                }
                orow[j] = acc;
                j += 1;
            }
        }
        out
    }

    /// Max absolute elementwise difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Pad to shape `(rows_to, cols_to)` with `fill`, keeping data top-left.
    pub fn padded(&self, rows_to: usize, cols_to: usize, fill: f32) -> Matrix {
        assert!(rows_to >= self.rows && cols_to >= self.cols);
        let mut out = Matrix::filled(rows_to, cols_to, fill);
        out.set_block(0, 0, self);
        out
    }
}

impl<'a> MatrixView<'a> {
    /// View over a contiguous row-major slice (stride = cols).
    pub fn from_slice(data: &'a [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        MatrixView { data, rows, cols, stride: cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.stride..r * self.stride + self.cols]
    }

    /// Zero-copy sub-window `[r0..r0+h) × [c0..c0+w)` of this view.
    #[inline]
    pub fn sub(&self, r0: usize, c0: usize, h: usize, w: usize) -> MatrixView<'a> {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        if h == 0 || w == 0 {
            return MatrixView { data: &[], rows: h, cols: w, stride: 0 };
        }
        let start = r0 * self.stride + c0;
        let end = start + (h - 1) * self.stride + w;
        MatrixView { data: &self.data[start..end], rows: h, cols: w, stride: self.stride }
    }

    /// Materialize into an owned matrix (the only copying operation here).
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(self.row(r));
        }
        out
    }

    /// `self · otherᵀ` into a fresh matrix (see [`matmul_nt_into`]).
    pub fn matmul_nt(&self, other: MatrixView<'_>) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows());
        matmul_nt_into(*self, other, &mut out.view_mut());
        out
    }
}

impl<'a> MatrixViewMut<'a> {
    /// Mutable view over a contiguous row-major slice (stride = cols).
    pub fn from_slice(data: &'a mut [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        MatrixViewMut { data, rows, cols, stride: cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.stride..r * self.stride + self.cols]
    }

    /// Read-only reborrow.
    #[inline]
    pub fn as_view(&self) -> MatrixView<'_> {
        MatrixView { data: self.data, rows: self.rows, cols: self.cols, stride: self.stride }
    }
}

// ---------------- the cache-blocked microkernel ----------------

/// Register tile height (A rows per microkernel invocation).
const MR: usize = 8;
/// Register tile width (B rows per microkernel invocation).
const NR: usize = 4;
/// Cache panel of A rows — one panel's rows stay L2-resident across the
/// inner j sweep.
const MC: usize = 64;
/// Cache panel of B rows — reused across every A row of the i panel.
const NC: usize = 64;

// The matmul-nt kernel family below is the engine's compute hot path —
// every correlation tile and similarity tile runs through it. No locks, no
// stray unsafe: the analyzer (`cargo xtask analyze`) audits the region.
// analyze: hot-path begin(matmul-nt)

/// `dst = a · bᵀ` — the shared all-pairs kernel (EXPERIMENTS.md §Perf).
///
/// Blocked over i (A rows) and j (B rows) only; the k (inner) dimension is
/// **never split**: each of the `mr×nr` register accumulators performs its
/// whole dot product in strict ascending-k order with a single `+=`, so the
/// result is bitwise identical to the naive triple loop
/// ([`matmul_nt_reference`]) — the invariant the distributed/single-node
/// consistency tests pin. Writes into caller-owned storage; allocates
/// nothing.
pub fn matmul_nt_into(a: MatrixView<'_>, b: MatrixView<'_>, dst: &mut MatrixViewMut<'_>) {
    let (n, k) = a.shape();
    let (m, k2) = b.shape();
    assert_eq!(k, k2, "inner dimension mismatch");
    assert_eq!(dst.shape(), (n, m), "output shape mismatch");
    let mut jp = 0;
    while jp < m {
        let jh = NC.min(m - jp);
        let mut ip = 0;
        while ip < n {
            let ih = MC.min(n - ip);
            let mut i0 = ip;
            while i0 < ip + ih {
                let mr = MR.min(ip + ih - i0);
                let mut j0 = jp;
                while j0 < jp + jh {
                    let nr = NR.min(jp + jh - j0);
                    if mr == MR && nr == NR {
                        micro_full(a, b, i0, j0, k, dst);
                    } else {
                        micro_edge(a, b, i0, j0, mr, nr, k, dst);
                    }
                    j0 += nr;
                }
                i0 += mr;
            }
            ip += ih;
        }
        jp += jh;
    }
}

/// Full MR×NR register tile: 32 independent strict-k-order accumulators.
#[inline]
fn micro_full(a: MatrixView<'_>, b: MatrixView<'_>, i0: usize, j0: usize, k: usize, dst: &mut MatrixViewMut<'_>) {
    let ar: [&[f32]; MR] = std::array::from_fn(|r| a.row(i0 + r));
    let br: [&[f32]; NR] = std::array::from_fn(|c| b.row(j0 + c));
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..k {
        let bv = [br[0][l], br[1][l], br[2][l], br[3][l]];
        for r in 0..MR {
            let av = ar[r][l];
            acc[r][0] += av * bv[0];
            acc[r][1] += av * bv[1];
            acc[r][2] += av * bv[2];
            acc[r][3] += av * bv[3];
        }
    }
    for (r, row_acc) in acc.iter().enumerate() {
        dst.row_mut(i0 + r)[j0..j0 + NR].copy_from_slice(row_acc);
    }
}

/// Ragged-edge tile (`mr ≤ MR`, `nr ≤ NR`): same accumulator discipline.
fn micro_edge(
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    k: usize,
    dst: &mut MatrixViewMut<'_>,
) {
    let mut ar: [&[f32]; MR] = [&[]; MR];
    for (r, slot) in ar.iter_mut().enumerate().take(mr) {
        *slot = a.row(i0 + r);
    }
    let mut br: [&[f32]; NR] = [&[]; NR];
    for (c, slot) in br.iter_mut().enumerate().take(nr) {
        *slot = b.row(j0 + c);
    }
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..k {
        let mut bv = [0.0f32; NR];
        for c in 0..nr {
            bv[c] = br[c][l];
        }
        for r in 0..mr {
            let av = ar[r][l];
            for c in 0..nr {
                acc[r][c] += av * bv[c];
            }
        }
    }
    for r in 0..mr {
        dst.row_mut(i0 + r)[j0..j0 + nr].copy_from_slice(&acc[r][..nr]);
    }
}

/// Naive triple-loop `a · bᵀ` — the bitwise reference every optimized
/// variant must match exactly (pinned by `blocked_matmul_is_bitwise_naive`).
#[doc(hidden)]
pub fn matmul_nt_reference(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "inner dimension mismatch");
    let (n, m, k) = (a.rows(), b.rows(), a.cols());
    let mut out = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            let (ra, rb) = (a.row(i), b.row(j));
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += ra[l] * rb[l];
            }
            out[(i, j)] = acc;
        }
    }
    out
}

/// `a · bᵀ` with A's rows panelled across a thread pool — the per-rank
/// "OpenMP" path for leader/direct full-matrix products. Each task owns a
/// disjoint row panel of the output; element results are bitwise identical
/// to [`Matrix::matmul_nt`] (same kernel, same k order).
pub fn matmul_nt_pooled(a: &Matrix, b: &Matrix, pool: &ThreadPool) -> Matrix {
    let (n, k) = a.shape();
    assert_eq!(k, b.cols(), "inner dimension mismatch");
    let m = b.rows();
    let mut out = Matrix::zeros(n, m);
    {
        let out_ptr = crate::pool::SendPtr(out.as_mut_slice().as_mut_ptr());
        pool.parallel_for_chunked(n, |range| {
            // SAFETY: each task writes a disjoint row range of `out`, and
            // `out` outlives the blocking parallel_for_chunked call.
            // analyze: allow(unsafe): the SAFETY argument above is the audit
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(range.start * m), range.len() * m)
            };
            let mut dview = MatrixViewMut::from_slice(dst, range.len(), m);
            matmul_nt_into(a.view_rows(range), b.view(), &mut dview);
        });
    }
    out
}

// analyze: hot-path end(matmul-nt)

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl Index<(usize, usize)> for MatrixView<'_> {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.stride + c]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        for r in 0..show_r {
            let show_c = self.cols.min(8);
            let vals: Vec<String> = self.row(r)[..show_c].iter().map(|v| format!("{v:8.4}")).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > show_c { ", …" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl fmt::Debug for MatrixView<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatrixView {}x{} (stride {})", self.rows, self.cols, self.stride)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn construction_and_index() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        let _ = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn block_round_trip() {
        let m = Matrix::from_fn(6, 6, |r, c| (r * 6 + c) as f32);
        let b = m.block(2, 3, 2, 2);
        assert_eq!(b[(0, 0)], 15.0);
        assert_eq!(b[(1, 1)], 22.0);
        let mut z = Matrix::zeros(6, 6);
        z.set_block(2, 3, &b);
        assert_eq!(z[(2, 3)], 15.0);
        assert_eq!(z[(3, 4)], 22.0);
        assert_eq!(z[(0, 0)], 0.0);
    }

    #[test]
    fn view_block_matches_copy() {
        let m = Matrix::from_fn(9, 7, |r, c| (r * 31 + c) as f32);
        for (r0, c0, h, w) in [(0, 0, 9, 7), (2, 3, 4, 2), (8, 6, 1, 1), (3, 0, 0, 5), (0, 2, 4, 0)] {
            let v = m.view_block(r0, c0, h, w);
            let b = m.block(r0, c0, h, w);
            assert_eq!(v.shape(), b.shape());
            assert_eq!(v.to_matrix(), b, "view_block({r0},{c0},{h},{w})");
            for i in 0..h {
                assert_eq!(v.row(i), b.row(i));
            }
        }
    }

    #[test]
    fn view_sub_composes() {
        let m = Matrix::from_fn(10, 10, |r, c| (r * 10 + c) as f32);
        let outer = m.view_block(1, 2, 8, 7);
        let inner = outer.sub(2, 1, 3, 4);
        assert_eq!(inner.to_matrix(), m.block(3, 3, 3, 4));
        assert_eq!(inner[(0, 0)], m[(3, 3)]);
    }

    #[test]
    fn view_rows_is_full_width() {
        let m = Matrix::from_fn(6, 4, |r, c| (r * 4 + c) as f32);
        let v = m.view_rows(2..5);
        assert_eq!(v.shape(), (3, 4));
        assert_eq!(v.row(0), m.row(2));
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn transpose_blocked_matches_naive_large() {
        // Shapes straddling the 32×32 tile boundary.
        let mut rng = Rng::new(41);
        for (n, m) in [(1usize, 1usize), (31, 33), (32, 32), (70, 45), (33, 96)] {
            let a = Matrix::from_fn(n, m, |_, _| rng.normal_f32());
            let t = a.transpose();
            assert_eq!(t.shape(), (m, n));
            for r in 0..n {
                for c in 0..m {
                    assert_eq!(t[(c, r)], a[(r, c)], "({n},{m}) at ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn set_block_transposed_matches_transpose_copy() {
        let mut rng = Rng::new(43);
        for (h, w) in [(1usize, 1usize), (5, 9), (32, 32), (40, 33), (64, 17)] {
            let b = Matrix::from_fn(h, w, |_, _| rng.normal_f32());
            let mut direct = Matrix::zeros(w + 3, h + 2);
            direct.set_block_transposed(3, 2, &b);
            let mut viacopy = Matrix::zeros(w + 3, h + 2);
            viacopy.set_block(3, 2, &b.transpose());
            assert_eq!(direct, viacopy, "shape ({h},{w})");
        }
    }

    #[test]
    fn matmul_nt_vs_manual() {
        // A (2x3) · B(2x3)^T = 2x2
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(2, 3, vec![1., 0., 1., 0., 1., 0.]);
        let c = a.matmul_nt(&b);
        assert_eq!(c[(0, 0)], 4.0); // 1+3
        assert_eq!(c[(0, 1)], 2.0);
        assert_eq!(c[(1, 0)], 10.0); // 4+6
        assert_eq!(c[(1, 1)], 5.0);
    }

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_fn(4, 4, |r, c| (r + c) as f32);
        let i = Matrix::eye(4);
        // a · iᵀ = a (i symmetric)
        assert_eq!(a.matmul_nt(&i), a);
    }

    #[test]
    fn blocked_matmul_is_bitwise_naive() {
        // Randomized ragged shapes around every tile/panel boundary —
        // the strict-k-order invariant means *exact* equality, not epsilon.
        let mut rng = Rng::new(97);
        let mut shapes = vec![
            (0usize, 0usize, 0usize),
            (0, 3, 5),
            (3, 0, 5),
            (1, 1, 0),
            (1, 1, 1),
            (7, 3, 11),
            (8, 4, 16),
            (9, 5, 17),
            (63, 65, 33),
            (64, 64, 64),
            (65, 63, 100),
            (130, 70, 129),
        ];
        for _ in 0..8 {
            shapes.push((1 + rng.below(90), 1 + rng.below(90), 1 + rng.below(150)));
        }
        for (n, m, k) in shapes {
            let a = Matrix::from_fn(n, k, |_, _| rng.normal_f32());
            let b = Matrix::from_fn(m, k, |_, _| rng.normal_f32());
            let fast = a.matmul_nt(&b);
            let naive = matmul_nt_reference(&a, &b);
            let seed = a.matmul_nt_seed(&b);
            assert_eq!(fast.as_slice(), naive.as_slice(), "blocked != naive ({n},{m},{k})");
            assert_eq!(seed.as_slice(), naive.as_slice(), "seed != naive ({n},{m},{k})");
        }
    }

    #[test]
    fn matmul_on_views_avoids_copies() {
        // Tile product straight out of a larger standardized matrix.
        let mut rng = Rng::new(7);
        let z = Matrix::from_fn(40, 25, |_, _| rng.normal_f32());
        let va = z.view_block(3, 0, 12, 25);
        let vb = z.view_block(20, 0, 9, 25);
        let from_views = va.matmul_nt(vb);
        let from_copies = z.block(3, 0, 12, 25).matmul_nt(&z.block(20, 0, 9, 25));
        assert_eq!(from_views.as_slice(), from_copies.as_slice());
    }

    #[test]
    fn matmul_into_writes_caller_scratch() {
        let mut rng = Rng::new(13);
        let a = Matrix::from_fn(10, 20, |_, _| rng.normal_f32());
        let b = Matrix::from_fn(6, 20, |_, _| rng.normal_f32());
        let mut scratch = vec![7.0f32; 10 * 6];
        {
            let mut dst = MatrixViewMut::from_slice(&mut scratch, 10, 6);
            matmul_nt_into(a.view(), b.view(), &mut dst);
        }
        let expect = matmul_nt_reference(&a, &b);
        assert_eq!(&scratch[..], expect.as_slice());
    }

    #[test]
    fn matmul_pooled_is_bitwise_serial() {
        let pool = ThreadPool::new(4);
        let mut rng = Rng::new(29);
        for (n, m, k) in [(1usize, 1usize, 4usize), (33, 17, 40), (100, 64, 31)] {
            let a = Matrix::from_fn(n, k, |_, _| rng.normal_f32());
            let b = Matrix::from_fn(m, k, |_, _| rng.normal_f32());
            let serial = a.matmul_nt(&b);
            let pooled = matmul_nt_pooled(&a, &b, &pool);
            assert_eq!(serial.as_slice(), pooled.as_slice(), "({n},{m},{k})");
        }
    }

    #[test]
    fn select_rows_works() {
        let m = Matrix::from_fn(5, 2, |r, _| r as f32);
        let s = m.select_rows(&[4, 0, 2]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s[(0, 0)], 4.0);
        assert_eq!(s[(1, 0)], 0.0);
        assert_eq!(s[(2, 0)], 2.0);
    }

    #[test]
    fn padded_keeps_content() {
        let m = Matrix::from_fn(2, 2, |r, c| (r * 2 + c) as f32 + 1.0);
        let p = m.padded(4, 3, 0.0);
        assert_eq!(p.shape(), (4, 3));
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(3, 2)], 0.0);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Matrix::filled(2, 2, 1.0);
        let mut b = a.clone();
        b[(1, 0)] = 1.5;
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
