//! Dense row-major `f32` matrix — the core numeric container.
//!
//! Gene-expression inputs are `N×M` (genes × samples); correlation blocks are
//! `B×B`. Row-major layout matches both the XLA literal layout used by the
//! runtime bridge and the cache-friendly row iteration of the native kernels.

use std::fmt;
use std::ops::{Index, IndexMut};

#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// From an existing row-major buffer (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Logical size in bytes of the backing buffer.
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Copy a sub-block `[r0..r0+h) × [c0..c0+w)` into a new matrix.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for r in 0..h {
            out.row_mut(r).copy_from_slice(&self.row(r0 + r)[c0..c0 + w]);
        }
        out
    }

    /// Write a block into this matrix at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Matrix) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols, "block out of range");
        for r in 0..b.rows {
            let dst = r0 + r;
            self.data[dst * self.cols + c0..dst * self.cols + c0 + b.cols]
                .copy_from_slice(b.row(r));
        }
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (i, &c) in idx.iter().enumerate() {
                dst[i] = src[c];
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Plain `self · otherᵀ` (used for standardized-row correlation:
    /// rows of both operands are observations over the same M columns).
    ///
    /// Hot path (EXPERIMENTS.md §Perf): the j dimension is processed four
    /// rows at a time so each `a[l]` load feeds four independent dot-product
    /// chains (4× ILP) while every individual dot product still accumulates
    /// in strict l-order — results are bitwise identical to the naive loop,
    /// which keeps the single-node and distributed paths exactly consistent.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "inner dimension mismatch");
        let (n, m, k) = (self.rows, other.rows, self.cols);
        let mut out = Matrix::zeros(n, m);
        let bdat = &other.data;
        for i in 0..n {
            let a = self.row(i);
            let orow = &mut out.data[i * m..(i + 1) * m];
            let mut j = 0usize;
            while j + 4 <= m {
                let b0 = &bdat[j * k..(j + 1) * k];
                let b1 = &bdat[(j + 1) * k..(j + 2) * k];
                let b2 = &bdat[(j + 2) * k..(j + 3) * k];
                let b3 = &bdat[(j + 3) * k..(j + 4) * k];
                let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for l in 0..k {
                    let av = a[l];
                    c0 += av * b0[l];
                    c1 += av * b1[l];
                    c2 += av * b2[l];
                    c3 += av * b3[l];
                }
                orow[j] = c0;
                orow[j + 1] = c1;
                orow[j + 2] = c2;
                orow[j + 3] = c3;
                j += 4;
            }
            while j < m {
                let b = &bdat[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for l in 0..k {
                    acc += a[l] * b[l];
                }
                orow[j] = acc;
                j += 1;
            }
        }
        out
    }

    /// Max absolute elementwise difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Pad to shape `(rows_to, cols_to)` with `fill`, keeping data top-left.
    pub fn padded(&self, rows_to: usize, cols_to: usize, fill: f32) -> Matrix {
        assert!(rows_to >= self.rows && cols_to >= self.cols);
        let mut out = Matrix::filled(rows_to, cols_to, fill);
        out.set_block(0, 0, self);
        out
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        for r in 0..show_r {
            let show_c = self.cols.min(8);
            let vals: Vec<String> = self.row(r)[..show_c].iter().map(|v| format!("{v:8.4}")).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > show_c { ", …" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_index() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m[(0, 0)], 0.0);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        let _ = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn block_round_trip() {
        let m = Matrix::from_fn(6, 6, |r, c| (r * 6 + c) as f32);
        let b = m.block(2, 3, 2, 2);
        assert_eq!(b[(0, 0)], 15.0);
        assert_eq!(b[(1, 1)], 22.0);
        let mut z = Matrix::zeros(6, 6);
        z.set_block(2, 3, &b);
        assert_eq!(z[(2, 3)], 15.0);
        assert_eq!(z[(3, 4)], 22.0);
        assert_eq!(z[(0, 0)], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn matmul_nt_vs_manual() {
        // A (2x3) · B(2x3)^T = 2x2
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(2, 3, vec![1., 0., 1., 0., 1., 0.]);
        let c = a.matmul_nt(&b);
        assert_eq!(c[(0, 0)], 4.0); // 1+3
        assert_eq!(c[(0, 1)], 2.0);
        assert_eq!(c[(1, 0)], 10.0); // 4+6
        assert_eq!(c[(1, 1)], 5.0);
    }

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_fn(4, 4, |r, c| (r + c) as f32);
        let i = Matrix::eye(4);
        // a · iᵀ = a (i symmetric)
        assert_eq!(a.matmul_nt(&i), a);
    }

    #[test]
    fn select_rows_works() {
        let m = Matrix::from_fn(5, 2, |r, _| r as f32);
        let s = m.select_rows(&[4, 0, 2]);
        assert_eq!(s.rows(), 3);
        assert_eq!(s[(0, 0)], 4.0);
        assert_eq!(s[(1, 0)], 0.0);
        assert_eq!(s[(2, 0)], 2.0);
    }

    #[test]
    fn padded_keeps_content() {
        let m = Matrix::from_fn(2, 2, |r, c| (r * 2 + c) as f32 + 1.0);
        let p = m.padded(4, 3, 0.0);
        assert_eq!(p.shape(), (4, 3));
        assert_eq!(p[(1, 1)], 4.0);
        assert_eq!(p[(3, 2)], 0.0);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Matrix::filled(2, 2, 1.0);
        let mut b = a.clone();
        b[(1, 0)] = 1.5;
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
