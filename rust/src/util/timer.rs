//! Wall-clock timing helpers for the bench harness and coordinator metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Per-thread CPU-time stopwatch (CLOCK_THREAD_CPUTIME_ID).
///
/// Unlike wall clock, this excludes time the thread spent descheduled —
/// essential when simulating P ranks on fewer physical cores: a rank's
/// "compute time" must not include the other ranks' execution.
#[derive(Debug)]
pub struct ThreadCpuTimer {
    start: f64,
}

impl ThreadCpuTimer {
    pub fn start() -> Self {
        Self { start: thread_cpu_secs() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        thread_cpu_secs() - self.start
    }
}

/// Current thread CPU time in seconds.
pub fn thread_cpu_secs() -> f64 {
    use crate::util::sys;
    let mut ts = sys::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: plain FFI call writing into a stack-owned, correctly-sized
    // timespec; no aliasing, no retained pointers.
    let r = unsafe { sys::clock_gettime(sys::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    if r != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Format a duration in adaptive units.
pub fn format_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.3} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(secs >= 0.0);
    }

    #[test]
    fn format_adaptive() {
        assert!(format_secs(5e-9).ends_with("ns"));
        assert!(format_secs(5e-6).ends_with("µs"));
        assert!(format_secs(5e-3).ends_with("ms"));
        assert!(format_secs(5.0).ends_with(" s"));
        assert!(format_secs(300.0).ends_with("min"));
    }
}
