//! Small self-contained utilities shared by every subsystem.
//!
//! The offline build environment only ships the `xla` crate closure, so the
//! usual ecosystem crates (rand, serde, itertools, ...) are reimplemented
//! here as minimal, well-tested substrates.

pub mod prng;
pub mod stats;
pub mod json;
pub mod sys;
pub mod timer;
pub mod bytes;
pub mod matrix;

pub use matrix::{matmul_nt_into, matmul_nt_pooled, Matrix, MatrixView, MatrixViewMut};
pub use prng::Rng;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Number of unordered pairs over `n` items, excluding self-pairs:
/// `C(n,2) = n(n-1)/2`.
#[inline]
pub fn n_choose_2(n: usize) -> usize {
    n * n.saturating_sub(1) / 2
}

/// Number of unordered pairs over `n` items *including* self-pairs
/// (the dataset-level pairing of the paper's Eq. 6): `n(n+1)/2`.
#[inline]
pub fn pairs_with_self(n: usize) -> usize {
    n * (n + 1) / 2
}

/// `isqrt` for usize (floor of the square root).
#[inline]
pub fn isqrt(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as usize;
    // Correct potential floating-point drift in either direction; overflow
    // of x*x counts as "too big" (checked_mul, not saturating: saturation
    // would loop forever at n = usize::MAX).
    while x.checked_mul(x).map_or(true, |v| v > n) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).is_some_and(|v| v <= n) {
        x += 1;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(0, 3), 0);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(5, 4), 8);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(0, 4), 0);
    }

    #[test]
    fn pair_counts() {
        assert_eq!(n_choose_2(7), 21); // paper Fig. 1: seven elements, 21 pairs
        assert_eq!(n_choose_2(0), 0);
        assert_eq!(n_choose_2(1), 0);
        assert_eq!(pairs_with_self(7), 28);
    }

    #[test]
    fn isqrt_exhaustive_small() {
        for n in 0..10_000usize {
            let r = isqrt(n);
            assert!(r * r <= n && (r + 1) * (r + 1) > n, "isqrt({n}) = {r}");
        }
    }

    #[test]
    fn isqrt_large() {
        assert_eq!(isqrt(usize::MAX), (1usize << 32) - 1);
        assert_eq!(isqrt(1usize << 62), 1usize << 31);
        assert_eq!(isqrt((1usize << 62) - 1), (1usize << 31) - 1);
    }
}
