//! Integration: the XLA/PJRT backend vs the native reference, and a full
//! distributed run on the XLA backend.
//!
//! These tests need `make artifacts` (they are skipped with a message when
//! `artifacts/manifest.json` is absent, so `cargo test` stays green on a
//! fresh checkout).

use quorall::config::{BackendKind, PcitMode, RunConfig};
use quorall::coordinator::{run_distributed_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::pcit::standardize_rows;
use quorall::runtime::{executor_for, NativeBackend, TileExecutor};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping XLA integration test: run `make artifacts` first");
        None
    }
}

fn rand_corr(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_fn(r, c, |_, _| rng.f32() * 1.9 - 0.95)
}

#[test]
fn xla_corr_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = executor_for(BackendKind::Xla, dir).expect("load artifacts");
    let native = NativeBackend::new();
    let mut rng = Rng::new(5);
    // Mix of exact-fit, padded, and chunked shapes.
    for (a, b, m) in [(128usize, 128usize, 128usize), (64, 32, 20), (100, 90, 130), (128, 128, 300), (200, 150, 48), (1, 1, 3)] {
        let x = Matrix::from_fn(a, m, |_, _| rng.normal_f32());
        let y = Matrix::from_fn(b, m, |_, _| rng.normal_f32());
        let za = standardize_rows(&x);
        let zb = standardize_rows(&y);
        let got = xla.corr_tile(&za, &zb);
        let want = native.corr_tile(&za, &zb);
        assert_eq!(got.shape(), want.shape());
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-5, "corr tile ({a},{b},m={m}) diff {diff}");
    }
}

#[test]
fn xla_pcit_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = executor_for(BackendKind::Xla, dir).expect("load artifacts");
    let native = NativeBackend::new();
    let mut rng = Rng::new(11);
    for (a, b, z) in [(128usize, 128usize, 128usize), (64, 64, 64), (50, 70, 200), (128, 128, 1000), (10, 5, 7)] {
        let cxy = rand_corr(&mut rng, a, b);
        let rxz = rand_corr(&mut rng, a, z);
        let ryz = rand_corr(&mut rng, b, z);
        let got = xla.pcit_tile(&cxy, &rxz, &ryz);
        let want = native.pcit_tile(&cxy, &rxz, &ryz);
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "pcit flags ({a},{b},z={z}) differ"
        );
    }
}

#[test]
fn xla_distributed_run_matches_single_node() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = executor_for(BackendKind::Xla, dir).expect("load artifacts");
    let d = ExpressionDataset::generate(SyntheticSpec {
        genes: 96,
        samples: 24,
        modules: 4,
        noise: 0.5,
        seed: 31,
    });
    let single = run_single_node(&d, 2, None);
    let cfg = RunConfig { ranks: 4, mode: PcitMode::QuorumExact, backend: BackendKind::Xla, ..RunConfig::default() };
    let rep = run_distributed_pcit(&cfg, &d, exec).unwrap();
    assert!(
        rep.network.same_edges(&single.network),
        "XLA-backed distributed PCIT must equal single-node: {} vs {}",
        rep.network.n_edges(),
        single.network.n_edges()
    );
}

#[test]
fn xla_backend_is_shareable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = executor_for(BackendKind::Xla, dir).expect("load artifacts");
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let e = exec.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let x = Matrix::from_fn(64, 32, |_, _| rng.normal_f32());
            let za = standardize_rows(&x);
            let tile = e.corr_tile(&za, &za);
            // Diagonal of a self-correlation is 1.
            for i in 0..64 {
                assert!((tile[(i, i)] - 1.0).abs() < 1e-4);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
