//! Integration: the XLA/PJRT backend vs the native reference, a full
//! distributed run on the XLA backend, and view-vs-copy equivalence for
//! every `TileExecutor` path.
//!
//! The XLA tests need `make artifacts` and a `--features xla` build (they
//! are skipped with a message when `artifacts/manifest.json` is absent, so
//! `cargo test` stays green on a fresh checkout).

use quorall::config::{BackendKind, PcitMode, RunConfig};
use quorall::coordinator::{run_distributed_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::pcit::standardize_rows;
use quorall::runtime::{executor_for, NativeBackend, TileExecutor};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping XLA integration test: run `make artifacts` first");
        None
    }
}

fn rand_corr(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    Matrix::from_fn(r, c, |_, _| rng.f32() * 1.9 - 0.95)
}

/// Both tile paths, computed from borrowed views of one backing matrix vs
/// from materialized copies, must agree exactly for any executor.
fn assert_view_copy_equivalence(exec: &dyn TileExecutor) {
    let mut rng = Rng::new(71);
    // corr path: standardized backing matrix, tiles from ragged offsets.
    let x = Matrix::from_fn(50, 33, |_, _| rng.normal_f32());
    let z = standardize_rows(&x);
    for (r0, h, r1, w) in [(0usize, 13usize, 13usize, 17usize), (5, 8, 30, 20), (49, 1, 0, 1)] {
        let via_views = exec.corr_tile(z.view_block(r0, 0, h, 33), z.view_block(r1, 0, w, 33));
        let (ca, cb) = (z.block(r0, 0, h, 33), z.block(r1, 0, w, 33));
        let via_copies = exec.corr_tile(ca.view(), cb.view());
        assert_eq!(
            via_views.as_slice(),
            via_copies.as_slice(),
            "{}: corr tile views != copies at ({r0},{h},{r1},{w})",
            exec.name()
        );
    }
    // pcit path: a correlation-like backing matrix, windows vs copies.
    let c = rand_corr(&mut rng, 24, 40);
    let cxy_v = c.view_block(0, 8, 10, 12);
    let rxz_v = c.view_block(0, 0, 10, 40);
    let ryz_v = c.view_block(12, 0, 12, 40);
    let via_views = exec.pcit_tile(cxy_v, rxz_v, ryz_v);
    let (cc, rr, yy) = (c.block(0, 8, 10, 12), c.block(0, 0, 10, 40), c.block(12, 0, 12, 40));
    let via_copies = exec.pcit_tile(cc.view(), rr.view(), yy.view());
    assert_eq!(
        via_views.as_slice(),
        via_copies.as_slice(),
        "{}: pcit tile views != copies",
        exec.name()
    );
}

#[test]
fn native_view_copy_equivalence() {
    assert_view_copy_equivalence(&NativeBackend::new());
}

#[test]
fn xla_view_copy_equivalence() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = match executor_for(BackendKind::Xla, dir) {
        Ok(e) => e,
        // Without the feature the stub always errors — skip; with it,
        // a load failure is a real regression and must fail loudly.
        Err(e) if !cfg!(feature = "xla") => {
            eprintln!("skipping XLA integration test: {e:#}");
            return;
        }
        Err(e) => panic!("load artifacts: {e:#}"),
    };
    assert_view_copy_equivalence(xla.as_ref());
}

#[test]
fn xla_corr_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = match executor_for(BackendKind::Xla, dir) {
        Ok(e) => e,
        // Without the feature the stub always errors — skip; with it,
        // a load failure is a real regression and must fail loudly.
        Err(e) if !cfg!(feature = "xla") => {
            eprintln!("skipping XLA integration test: {e:#}");
            return;
        }
        Err(e) => panic!("load artifacts: {e:#}"),
    };
    let native = NativeBackend::new();
    let mut rng = Rng::new(5);
    // Mix of exact-fit, padded, and chunked shapes.
    for (a, b, m) in [(128usize, 128usize, 128usize), (64, 32, 20), (100, 90, 130), (128, 128, 300), (200, 150, 48), (1, 1, 3)] {
        let x = Matrix::from_fn(a, m, |_, _| rng.normal_f32());
        let y = Matrix::from_fn(b, m, |_, _| rng.normal_f32());
        let za = standardize_rows(&x);
        let zb = standardize_rows(&y);
        let got = xla.corr_tile(za.view(), zb.view());
        let want = native.corr_tile(za.view(), zb.view());
        assert_eq!(got.shape(), want.shape());
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-5, "corr tile ({a},{b},m={m}) diff {diff}");
    }
}

#[test]
fn xla_pcit_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = match executor_for(BackendKind::Xla, dir) {
        Ok(e) => e,
        // Without the feature the stub always errors — skip; with it,
        // a load failure is a real regression and must fail loudly.
        Err(e) if !cfg!(feature = "xla") => {
            eprintln!("skipping XLA integration test: {e:#}");
            return;
        }
        Err(e) => panic!("load artifacts: {e:#}"),
    };
    let native = NativeBackend::new();
    let mut rng = Rng::new(11);
    for (a, b, z) in [(128usize, 128usize, 128usize), (64, 64, 64), (50, 70, 200), (128, 128, 1000), (10, 5, 7)] {
        let cxy = rand_corr(&mut rng, a, b);
        let rxz = rand_corr(&mut rng, a, z);
        let ryz = rand_corr(&mut rng, b, z);
        let got = xla.pcit_tile(cxy.view(), rxz.view(), ryz.view());
        let want = native.pcit_tile(cxy.view(), rxz.view(), ryz.view());
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "pcit flags ({a},{b},z={z}) differ"
        );
    }
}

#[test]
fn xla_distributed_run_matches_single_node() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = match executor_for(BackendKind::Xla, dir) {
        Ok(e) => e,
        // Without the feature the stub always errors — skip; with it,
        // a load failure is a real regression and must fail loudly.
        Err(e) if !cfg!(feature = "xla") => {
            eprintln!("skipping XLA integration test: {e:#}");
            return;
        }
        Err(e) => panic!("load artifacts: {e:#}"),
    };
    let d = ExpressionDataset::generate(SyntheticSpec {
        genes: 96,
        samples: 24,
        modules: 4,
        noise: 0.5,
        seed: 31,
    });
    let single = run_single_node(&d, 2, None);
    let cfg = RunConfig { ranks: 4, mode: PcitMode::QuorumExact, backend: BackendKind::Xla, ..RunConfig::default() };
    let rep = run_distributed_pcit(&cfg, &d, exec).unwrap();
    assert!(
        rep.network.same_edges(&single.network),
        "XLA-backed distributed PCIT must equal single-node: {} vs {}",
        rep.network.n_edges(),
        single.network.n_edges()
    );
}

#[test]
fn xla_backend_is_shareable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let exec = match executor_for(BackendKind::Xla, dir) {
        Ok(e) => e,
        // Without the feature the stub always errors — skip; with it,
        // a load failure is a real regression and must fail loudly.
        Err(e) if !cfg!(feature = "xla") => {
            eprintln!("skipping XLA integration test: {e:#}");
            return;
        }
        Err(e) => panic!("load artifacts: {e:#}"),
    };
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let e = exec.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let x = Matrix::from_fn(64, 32, |_, _| rng.normal_f32());
            let za = standardize_rows(&x);
            let tile = e.corr_tile(za.view(), za.view());
            // Diagonal of a self-correlation is 1.
            for i in 0..64 {
                assert!((tile[(i, i)] - 1.0).abs() < 1e-4);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
