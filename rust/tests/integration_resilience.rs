//! Integration: quorum redundancy + failure injection (paper §6 future
//! work) — the system completes correct networks despite crashed ranks.
//! Resilient runs keep compute exactly-once (one primary owner per pair
//! over the r-fold placement); a dead rank's unfinished tasks are
//! re-assigned to surviving hosts mid-run. Mid-run kill phases and the
//! bitwise-parity matrix live in `integration_recovery.rs`.

use quorall::allpairs::RedundantAssignment;
use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{run_resilient_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::quorum::CyclicQuorumSet;
use quorall::runtime::NativeBackend;
use std::sync::Arc;

fn dataset(genes: usize) -> ExpressionDataset {
    ExpressionDataset::generate(SyntheticSpec {
        genes,
        samples: 28,
        modules: 5,
        noise: 0.5,
        seed: 77,
    })
}

fn cfg(ranks: usize) -> RunConfig {
    RunConfig {
        ranks,
        mode: PcitMode::QuorumLocal,
        use_pcit_significance: false, // threshold mode: pairwise-exact
        threshold: 0.5,
        ..RunConfig::default()
    }
}

#[test]
fn redundant_assignment_properties() {
    for p in [13usize, 16, 31] {
        let q = CyclicQuorumSet::with_redundancy(p, 2).unwrap();
        assert!(q.min_pair_coverage() >= 2, "P={p}");
        let r = RedundantAssignment::build(&q, 2);
        for a in 0..p {
            for b in a..p {
                let owners = r.owners(a, b);
                let hosts = q.pair_hosts(a, b);
                assert_eq!(owners.len(), 2, "P={p} pair ({a},{b})");
                for o in owners {
                    assert!(hosts.contains(o));
                }
            }
        }
        assert_eq!(r.min_replication(), 2);
        // Any single failure is survivable.
        for k in 0..p {
            assert!(r.covers_with_failures(&[k]), "P={p} kill {k}");
        }
    }
}

#[test]
fn coverage_check_detects_fatal_failures() {
    let q = CyclicQuorumSet::for_processes(7).unwrap();
    let r1 = RedundantAssignment::build(&q, 1);
    // With r = 1, killing any owner loses its pairs.
    let owner0_pairs = r1.tasks_for(0);
    if !owner0_pairs.is_empty() {
        assert!(!r1.covers_with_failures(&[0]));
    }
    // With r = 2 a single failure is survivable whenever every pair has
    // two hosts (true for the Fano quorums, k = 3 hosts per pair >= 2…
    // actually coverage multiplicity >= 1; check against reality).
    let r2 = RedundantAssignment::build(&q, 2);
    let survivable = (0..7).all(|k| r2.covers_with_failures(&[k]));
    let multi_host = (0..7).all(|a| (a..7).all(|b| q.pair_hosts(a, b).len() >= 2));
    assert_eq!(survivable, multi_host);
}

#[test]
fn resilient_run_without_failures_matches_single() {
    let d = dataset(90);
    let single = run_single_node(&d, 2, Some(0.5));
    let rep = run_resilient_pcit(&cfg(9), &d, Arc::new(NativeBackend::new()), 2, &[]).unwrap();
    assert!(rep.network.same_edges(&single.network));
}

#[test]
fn resilient_run_survives_crash() {
    let d = dataset(90);
    let single = run_single_node(&d, 2, Some(0.5));
    let p = 9;
    // Under the 2-fold cover any single rank death is survivable.
    let victim = 4;
    let rep = run_resilient_pcit(&cfg(p), &d, Arc::new(NativeBackend::new()), 2, &[victim]).unwrap();
    assert!(
        rep.network.same_edges(&single.network),
        "network must be complete despite rank {victim} crashing: {} vs {} edges",
        rep.network.n_edges(),
        single.network.n_edges()
    );
    assert_eq!(rep.stats.len(), p - 1, "only survivors report");
    assert_eq!(rep.dead_ranks, vec![victim]);
    // A scatter-killed rank computed nothing: every one of its primary
    // tasks must have been re-assigned and recovered.
    assert!(rep.recovered_tasks > 0, "recovery must have re-run the victim's tasks");
}

#[test]
fn insufficient_redundancy_is_detected() {
    let d = dataset(60);
    let p = 9;
    let q = CyclicQuorumSet::for_processes(p).unwrap();
    let r1 = RedundantAssignment::build(&q, 1);
    // Killing a rank that solely owns some pair must be rejected up front.
    let victim = (0..p).find(|&k| !r1.covers_with_failures(&[k]));
    if let Some(v) = victim {
        let err = run_resilient_pcit(&cfg(p), &d, Arc::new(NativeBackend::new()), 1, &[v]);
        assert!(err.is_err(), "must refuse to run with lost pairs");
    }
}

#[test]
fn resilient_pcit_mode_close_to_single() {
    // Full PCIT in local mode with a crash: approximate but close.
    let d = dataset(80);
    let single = run_single_node(&d, 2, None);
    let mut c = cfg(8);
    c.use_pcit_significance = true;
    let rep = run_resilient_pcit(&c, &d, Arc::new(NativeBackend::new()), 2, &[3]).unwrap();
    let j = rep.network.jaccard(&single.network);
    assert!(j > 0.4, "jaccard {j}");
}
