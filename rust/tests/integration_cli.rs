//! Integration: the `quorall` binary end to end (launcher surface).

use std::process::Command;

fn quorall() -> Command {
    Command::new(env!("CARGO_BIN_EXE_quorall"))
}

#[test]
fn help_lists_commands() {
    let out = quorall().arg("--help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["quorum", "pcit", "nbody", "sim", "info"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn quorum_generation() {
    let out = quorall().args(["quorum", "--p", "7", "--n", "700"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("all-pairs property: true"));
    assert!(text.contains("S_0"));
}

#[test]
fn quorum_table_subset() {
    let out = quorall()
        .args(["quorum", "--table", "--from", "4", "--to", "16"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("savings_vs_force"));
    assert!(text.lines().count() > 13);
}

#[test]
fn pcit_small_run_with_verify() {
    let out = quorall()
        .args([
            "pcit", "--ranks", "4", "--genes", "96", "--samples", "20", "--verify",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {text}\nstderr: {err}");
    assert!(text.contains("IDENTICAL"), "{text}");
}

#[test]
fn pcit_pipeline_flag_verifies_identical() {
    let out = quorall()
        .args([
            "pcit", "--ranks", "4", "--genes", "96", "--samples", "20", "--pipeline", "on",
            "--verify",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {text}\nstderr: {err}");
    assert!(text.contains("pipeline = on"), "{text}");
    assert!(text.contains("blocked-recv"), "{text}");
    assert!(text.contains("IDENTICAL"), "{text}");
}

#[test]
fn pcit_scatter_flag_verifies_identical() {
    let out = quorall()
        .args([
            "pcit", "--ranks", "4", "--genes", "96", "--samples", "20", "--scatter", "streamed",
            "--verify",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {text}\nstderr: {err}");
    assert!(text.contains("scatter = streamed"), "{text}");
    assert!(text.contains("first task at"), "{text}");
    assert!(text.contains("IDENTICAL"), "{text}");
}

#[test]
fn pcit_rejects_bad_scatter_value() {
    let out = quorall()
        .args(["pcit", "--ranks", "4", "--genes", "64", "--scatter", "sideways"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad --scatter"));
}

#[test]
fn pcit_recovers_from_mid_run_kill() {
    // Quorum-local threshold run with r = 2, rank 4 killed after its first
    // task: the leader must re-assign the orphans and finish cleanly.
    let out = quorall()
        .args([
            "pcit", "--ranks", "9", "--genes", "90", "--samples", "20", "--mode", "quorum-local",
            "--redundancy", "2", "--kill", "4", "--kill-at", "compute:1", "--recover", "on",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {text}\nstderr: {err}");
    assert!(text.contains("recovered from dead ranks [4]"), "{text}");
}

#[test]
fn pcit_rejects_bad_kill_at_value() {
    let out = quorall()
        .args(["pcit", "--ranks", "4", "--genes", "64", "--kill-at", "sideways"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("kill-at"), "{err}");
}

#[test]
fn pcit_rejects_bad_pipeline_value() {
    let out = quorall()
        .args(["pcit", "--ranks", "4", "--genes", "64", "--pipeline", "sideways"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad --pipeline"));
}

#[test]
fn pcit_writes_edges_csv() {
    let dir = std::env::temp_dir().join("quorall-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("edges.csv");
    let out = quorall()
        .args([
            "pcit",
            "--ranks",
            "4",
            "--genes",
            "64",
            "--samples",
            "16",
            "--out",
            out_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let csv = std::fs::read_to_string(&out_path).unwrap();
    assert!(csv.starts_with("gene_a,gene_b,correlation"));
    std::fs::remove_file(&out_path).ok();
}

#[test]
fn pcit_strategy_grid_identical() {
    let out = quorall()
        .args([
            "pcit", "--ranks", "4", "--genes", "64", "--samples", "16", "--strategy", "grid",
            "--verify",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{text}");
    assert!(text.contains("strategy = grid"), "{text}");
    assert!(text.contains("IDENTICAL"), "{text}");
}

#[test]
fn similarity_command_runs_all_strategies() {
    for strategy in ["cyclic", "grid", "full"] {
        let out = quorall()
            .args([
                "similarity", "--subjects", "48", "--dim", "16", "--ranks", "4", "--topk", "5",
                "--strategy", strategy,
            ])
            .output()
            .unwrap();
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(out.status.success(), "strategy {strategy}: {text}");
        assert!(text.contains("top-5"), "strategy {strategy}: {text}");
    }
}

#[test]
fn nbody_runs() {
    let out = quorall()
        .args(["nbody", "--bodies", "64", "--ranks", "4", "--steps", "5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("energy drift"));
}

#[test]
fn sim_prints_predictions() {
    let out = quorall().args(["sim", "--genes", "1000", "--max-ranks", "16"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("speedup"));
}

#[test]
fn bad_arguments_fail_cleanly() {
    let out = quorall().args(["pcit", "--mode", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    let out = quorall().args(["nonexistent-command"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}
