//! Integration: the protocol-conformance analyzer (`cargo xtask analyze`)
//! as a tier-1 gate.
//!
//! Two directions, both required:
//! * the real tree must be **clean** — any wire/dispatch/report/parity/
//!   hot-path drift fails `cargo test` with file:line findings, and
//! * the seeded-defect fixtures must each **fail loudly**, pinning that
//!   every check actually fires (a silently-vacuous analyzer would pass
//!   the clean-tree test forever).
//!
//! The fixture sources live in `xtask/fixtures/` and are shared with the
//! xtask unit tests via `xtask::fixtures`.

use std::process::Command;

use xtask::fixtures::{BAD_DISPATCH, BAD_HOTPATH, BAD_MESSAGES, BAD_WIRE};
use xtask::{check_dispatch, check_hot_paths, check_wire, render, DispatchSite, Src};

/// 1-based line of the first line containing `marker` — fixtures anchor
/// expected findings by marker comment, not by brittle line numbers.
fn line_of(text: &str, marker: &str) -> usize {
    text.lines()
        .position(|l| l.contains(marker))
        .map(|i| i + 1)
        .unwrap_or_else(|| panic!("marker {marker:?} not found"))
}

#[test]
fn analyzer_is_clean_on_the_tree() {
    let rust_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = xtask::analyze_tree(rust_dir).expect("analyze_tree I/O");
    assert!(
        findings.is_empty(),
        "protocol conformance findings (fix the code or pragma with a reason):\n{}",
        render(&findings)
    );
}

#[test]
fn fixture_bad_wire_reports_all_three_seeded_defects() {
    let messages = Src::new("fixtures/bad_messages.rs", BAD_MESSAGES);
    let wire = Src::new("fixtures/bad_wire.rs", BAD_WIRE);
    let findings = check_wire(&messages, &wire);
    assert_eq!(findings.len(), 3, "expected exactly 3 wire findings:\n{}", render(&findings));
    assert!(findings.iter().all(|f| f.check == "wire"), "{}", render(&findings));

    // Defect 1: Gamma encodes under Beta's tag — anchored at the encode arm.
    let dup = findings
        .iter()
        .find(|f| f.msg.contains("duplicate wire tag 1"))
        .unwrap_or_else(|| panic!("no duplicate-tag finding:\n{}", render(&findings)));
    assert_eq!(dup.file, "fixtures/bad_wire.rs");
    assert_eq!(dup.line, line_of(BAD_WIRE, "seeded duplicate-tag defect"));
    assert!(dup.msg.contains("Beta") && dup.msg.contains("Gamma"), "{}", dup.msg);

    // Defect 2: Gamma has no decode arm — anchored at the enum variant.
    // (The comment in take_message that *mentions* Gamma must not count.)
    let dec = findings
        .iter()
        .find(|f| f.msg.contains("no decode arm"))
        .unwrap_or_else(|| panic!("no missing-decode finding:\n{}", render(&findings)));
    assert_eq!(dec.file, "fixtures/bad_messages.rs");
    assert_eq!(dec.line, line_of(BAD_MESSAGES, "Gamma(u64)"));
    assert!(dec.msg.contains("Message::Gamma"), "{}", dec.msg);

    // Defect 3: Delta is missing from the round-trip property test.
    let rt = findings
        .iter()
        .find(|f| f.msg.contains("round-trip"))
        .unwrap_or_else(|| panic!("no round-trip-gap finding:\n{}", render(&findings)));
    assert_eq!(rt.file, "fixtures/bad_messages.rs");
    assert_eq!(rt.line, line_of(BAD_MESSAGES, "Delta,"));
    assert!(rt.msg.contains("Message::Delta"), "{}", rt.msg);
}

#[test]
fn fixture_bad_dispatch_reports_the_unmatched_variant_only() {
    let messages = Src::new("fixtures/bad_messages.rs", BAD_MESSAGES);
    let dispatch = Src::new("fixtures/bad_dispatch.rs", BAD_DISPATCH);
    let site = DispatchSite { name: "fixture dispatch", file: &dispatch, fns: &["dispatch"] };
    let findings = check_dispatch(&messages, &[site]);
    // Gamma is the one seeded defect; Alpha/Beta are matched and Delta is
    // pragma'd away — neither may fire.
    assert_eq!(findings.len(), 1, "expected exactly 1 dispatch finding:\n{}", render(&findings));
    let f = &findings[0];
    assert_eq!(f.check, "dispatch");
    assert_eq!(f.file, "fixtures/bad_dispatch.rs");
    assert_eq!(f.line, line_of(BAD_DISPATCH, "pub fn dispatch"));
    assert!(f.msg.contains("Message::Gamma"), "{}", f.msg);
    assert!(f.msg.contains("fixture dispatch"), "{}", f.msg);
}

#[test]
fn fixture_bad_hotpath_reports_the_mutex_but_honors_the_allow_pragma() {
    let hot = Src::new("fixtures/bad_hotpath.rs", BAD_HOTPATH);
    let findings = check_hot_paths(&[(&hot, "recv-loop")]);
    // The `.lock(` acquisition is the one seeded defect; the unsafe block
    // directly under its `allow(unsafe)` pragma must not fire.
    assert_eq!(findings.len(), 1, "expected exactly 1 hot-path finding:\n{}", render(&findings));
    let f = &findings[0];
    assert_eq!(f.check, "hot-path");
    assert_eq!(f.file, "fixtures/bad_hotpath.rs");
    assert_eq!(f.line, line_of(BAD_HOTPATH, "seeded hot-path Mutex defect"));
    assert!(f.msg.contains(".lock("), "{}", f.msg);
}

#[test]
fn findings_render_as_file_line_check() {
    let hot = Src::new("fixtures/bad_hotpath.rs", BAD_HOTPATH);
    let findings = check_hot_paths(&[(&hot, "recv-loop")]);
    let text = render(&findings);
    let want = format!(
        "fixtures/bad_hotpath.rs:{}: [hot-path]",
        line_of(BAD_HOTPATH, "seeded hot-path Mutex defect")
    );
    assert!(text.starts_with(&want), "render format drifted: {text}");
}

/// `--jsonl` emits one machine-readable report line whose keys the
/// analyzer guarantees cover every `DistributedReport` field.
#[test]
fn pcit_jsonl_emits_a_parseable_full_report() {
    let out = Command::new(env!("CARGO_BIN_EXE_quorall"))
        .args(["pcit", "--ranks", "3", "--genes", "96", "--samples", "20", "--jsonl"])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {text}\nstderr: {err}");
    let line = text
        .lines()
        .rev()
        .find(|l| l.starts_with('{'))
        .unwrap_or_else(|| panic!("no JSON line in output:\n{text}"));
    let json = quorall::util::json::Json::parse(line).expect("JSONL line parses");
    for key in [
        "network",
        "stats",
        "wall_secs",
        "quorum_size",
        "peak_bytes_per_rank",
        "total_comm_bytes",
        "coverage_ratio",
        "transport",
        "health",
    ] {
        assert!(json.get(key).is_some(), "JSONL report missing key {key}: {line}");
    }
    let stats = json.get("stats").and_then(|v| v.as_arr()).expect("stats array");
    assert_eq!(stats.len(), 3, "one stats object per rank");
}
