//! Integration: quorum properties at scale + cross-module invariants.

use quorall::allpairs::{all_pair_tasks, OwnerPolicy, PairAssignment};
use quorall::prop::forall;
use quorall::quorum::{
    diffset::{is_relaxed_difference_set, lower_bound_k},
    CyclicQuorumSet,
};

#[test]
fn paper_range_all_pairs_property() {
    // The paper's operational claim, for its full P range: every dataset
    // pair lives in at least one quorum.
    for p in 4..=111 {
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        assert!(q.verify_all_pairs_property(), "P={p}");
        assert!(q.verify_intersection_property(), "P={p}");
        assert!(q.verify_cover(), "P={p}");
    }
}

#[test]
fn paper_range_sizes_near_optimal() {
    let mut over = 0usize;
    for p in 4..=111 {
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        let lb = lower_bound_k(p);
        assert!(
            q.quorum_size() <= lb + 2,
            "P={p}: k={} vs lower bound {lb}",
            q.quorum_size()
        );
        if q.quorum_size() > lb {
            over += 1;
        }
    }
    // Most entries should be at the lower bound or +1.
    assert!(over <= 70, "too many above-bound sets: {over}");
}

#[test]
fn equal_work_equal_responsibility() {
    // Paper Eq. 12-13: every quorum the same size, every dataset in exactly
    // k quorums.
    for p in [7usize, 16, 31, 57, 96] {
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        let k = q.quorum_size();
        for i in 0..p {
            assert_eq!(q.quorum(i).len(), k, "P={p} S_{i}");
        }
        for d in 0..p {
            assert_eq!(q.holders(d).len(), k, "P={p} D_{d}");
        }
    }
}

#[test]
fn prop_shifted_sets_stay_difference_sets() {
    forall("cyclic shift preserves the difference property", 60, |g| {
        let p = g.usize_in(4, 111);
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        let shift = g.usize_in(0, p - 1);
        let shifted: Vec<usize> = q.base_set().iter().map(|&a| (a + shift) % p).collect();
        assert!(is_relaxed_difference_set(&shifted, p));
    });
}

#[test]
fn prop_ownership_partitions_work() {
    forall("ownership partitions the pair tasks", 30, |g| {
        let p = g.usize_in(4, 64);
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        let policy = *g.pick(&[OwnerPolicy::First, OwnerPolicy::Hash, OwnerPolicy::LeastLoaded]);
        let assignment = PairAssignment::build(&q, policy);
        assignment.verify(&q).unwrap();
        let mut collected: Vec<_> = (0..p).flat_map(|r| assignment.tasks_for(r)).collect();
        collected.sort();
        assert_eq!(collected, all_pair_tasks(p));
    });
}

#[test]
fn least_loaded_beats_first_policy_on_average() {
    let mut wins = 0;
    let mut total = 0;
    for p in (8..=96).step_by(8) {
        let q = CyclicQuorumSet::for_processes(p).unwrap();
        let ll = PairAssignment::build(&q, OwnerPolicy::LeastLoaded).imbalance();
        let first = PairAssignment::build(&q, OwnerPolicy::First).imbalance();
        total += 1;
        if ll <= first + 1e-12 {
            wins += 1;
        }
    }
    assert!(wins * 2 >= total, "least-loaded should usually win: {wins}/{total}");
}
