//! Integration: the TCP loopback transport must be observationally
//! equivalent to the in-memory transport — bitwise-identical app output
//! across every app × placement × protocol × scatter combination — while
//! adding what only real sockets can give: heartbeat failure detection of
//! a rank that goes dark without any goodbye (`--kill-at disconnect`),
//! and disconnect-driven recovery through the same task ledger the
//! in-memory kill flag feeds. Also the multi-failure soak: two ranks
//! killed in *different phases* of one run, with cascade re-orphaning
//! (work delegated to a rank that later dies itself is re-orphaned, not
//! lost), asserted by exactly-once pair coverage.

use quorall::apps::nbody::{run_distributed_nbody, Bodies};
use quorall::apps::similarity::run_distributed_similarity;
use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{
    run_app, run_resilient_pcit_at, BlockData, DistributedApp, EngineOptions, KillAt, Payload,
    TransportKind, WorkerCtx,
};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::quorum::Strategy;
use quorall::runtime::{Executor, NativeBackend};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::sync::Arc;

const P: usize = 9; // square, so the grid placement is natural
const STRATEGIES: [Strategy; 3] = [Strategy::Cyclic, Strategy::Grid, Strategy::Full];

fn exec() -> Executor {
    Arc::new(NativeBackend::new())
}

fn opts(strategy: Strategy, pipeline: bool, streamed: bool, kind: TransportKind) -> EngineOptions {
    let mut o = EngineOptions::new(P, strategy);
    o.pipeline = pipeline;
    o.streamed_scatter = streamed;
    o.transport = kind;
    o
}

// ---- Bitwise parity: every combination, memory vs TCP loopback ----

#[test]
fn tcp_similarity_matches_memory_bitwise_full_matrix() {
    let mut rng = Rng::new(21);
    let f = Matrix::from_fn(45, 8, |_, _| rng.normal_f32());
    let e = exec();
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            for streamed in [false, true] {
                let (base, base_rep) = run_distributed_similarity(
                    &f,
                    &e,
                    &opts(strategy, pipeline, streamed, TransportKind::Memory),
                )
                .unwrap();
                let (sim, rep) = run_distributed_similarity(
                    &f,
                    &e,
                    &opts(strategy, pipeline, streamed, TransportKind::Tcp),
                )
                .unwrap();
                assert_eq!(
                    sim.as_slice(),
                    base.as_slice(),
                    "strategy {} pipeline {pipeline} streamed {streamed}: TCP matrix diverged",
                    strategy.name()
                );
                assert_eq!(base_rep.transport, TransportKind::Memory);
                assert_eq!(rep.transport, TransportKind::Tcp);
                assert_eq!(rep.health.backend, "tcp");
                assert!(rep.health.detections.is_empty(), "failure-free run detected a death");
                assert!(
                    rep.total_comm_bytes > 0 && rep.scatter_comm_bytes > 0,
                    "socket byte accounting must survive the backend swap"
                );
            }
        }
    }
}

#[test]
fn tcp_nbody_matches_memory_bitwise_full_matrix() {
    let b = Bodies::random(45, 7);
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            for streamed in [false, true] {
                let mem = opts(strategy, pipeline, streamed, TransportKind::Memory);
                let (base, _) = run_distributed_nbody(&b, &mem).unwrap();
                let tcp = opts(strategy, pipeline, streamed, TransportKind::Tcp);
                let (forces, rep) = run_distributed_nbody(&b, &tcp).unwrap();
                for i in 0..b.n {
                    assert_eq!(
                        forces[i],
                        base[i],
                        "strategy {} pipeline {pipeline} streamed {streamed}: body {i} forces diverged over TCP",
                        strategy.name()
                    );
                }
                assert_eq!(rep.transport, TransportKind::Tcp);
            }
        }
    }
}

fn pcit_cfg(strategy: Strategy, pipeline: bool, streamed: bool, kind: TransportKind) -> RunConfig {
    RunConfig {
        ranks: P,
        mode: PcitMode::QuorumLocal,
        strategy,
        pipeline,
        streamed_scatter: streamed,
        use_pcit_significance: false, // threshold mode: pairwise-exact
        threshold: 0.5,
        transport: kind,
        ..RunConfig::default()
    }
}

#[test]
fn tcp_pcit_matches_memory_bitwise_full_matrix() {
    let d = ExpressionDataset::generate(SyntheticSpec {
        genes: 72,
        samples: 24,
        modules: 5,
        noise: 0.5,
        seed: 77,
    });
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            for streamed in [false, true] {
                let base_cfg = pcit_cfg(strategy, pipeline, streamed, TransportKind::Memory);
                let base =
                    run_resilient_pcit_at(&base_cfg, &d, exec(), 2, &[], KillAt::Scatter).unwrap();
                let cfg = pcit_cfg(strategy, pipeline, streamed, TransportKind::Tcp);
                let rep = run_resilient_pcit_at(&cfg, &d, exec(), 2, &[], KillAt::Scatter).unwrap();
                assert_eq!(
                    rep.network.edges,
                    base.network.edges,
                    "strategy {} pipeline {pipeline} streamed {streamed}: TCP network diverged",
                    strategy.name()
                );
                assert_eq!(rep.transport, TransportKind::Tcp);
            }
        }
    }
}

// ---- Disconnect: heartbeat-timeout detection + bitwise recovery ----

#[test]
fn tcp_disconnect_detected_by_heartbeat_timeout_and_recovered_bitwise() {
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    const VICTIM: usize = 4;
    for pipeline in [false, true] {
        // Failure-free memory baseline: the recovery target.
        let mut base_opts = opts(Strategy::Cyclic, pipeline, false, TransportKind::Memory);
        base_opts.redundancy = 2;
        base_opts.recover = true;
        let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();

        // TCP run where the victim goes dark mid-compute without any
        // goodbye: its sockets stay open but silent, so the leader can
        // only learn of the death from the heartbeat timeout.
        let mut o = opts(Strategy::Cyclic, pipeline, false, TransportKind::Tcp);
        o.redundancy = 2;
        o.recover = true;
        o.kill = vec![VICTIM];
        o.kill_at = KillAt::Disconnect { tasks: 1 };
        o.heartbeat_ms = 10;
        o.heartbeat_timeout_ms = 200;
        let (sim, rep) = run_distributed_similarity(&f, &e, &o).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: disconnect-recovered matrix diverged"
        );
        assert_eq!(rep.dead_ranks, vec![VICTIM]);
        assert!(rep.recovered_tasks > 0, "the victim's unfinished tasks must be recomputed");
        assert_eq!(rep.stats.len(), P - 1, "a dark rank must not report stats");
        let det = rep
            .health
            .detections
            .iter()
            .find(|d| d.rank == VICTIM)
            .expect("the failure detector must record the victim's death");
        assert_eq!(
            det.cause, "heartbeat-timeout",
            "a silent-socket death must be found by the heartbeat timeout, not an EOF"
        );
        assert!(
            det.latency_secs >= 0.15,
            "detection latency {} below the 200 ms silence window",
            det.latency_secs
        );
    }
}

// ---- Multi-failure soak: two ranks, two different phases, one run ----

fn soak_opts(strategy: Strategy, pipeline: bool, kind: TransportKind) -> EngineOptions {
    let mut o = opts(strategy, pipeline, false, kind);
    o.redundancy = 2;
    o.recover = true;
    o.kill = vec![2, 5];
    o.kill_at_list = vec![KillAt::Compute { tasks: 1 }, KillAt::Gather];
    o
}

#[test]
fn multi_failure_soak_bitwise_identical() {
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    for strategy in [Strategy::Cyclic, Strategy::Grid] {
        for pipeline in [false, true] {
            let mut base_opts = opts(strategy, pipeline, false, TransportKind::Memory);
            base_opts.redundancy = 2;
            base_opts.recover = true;
            let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
            for kind in [TransportKind::Memory, TransportKind::Tcp] {
                let (sim, rep) =
                    run_distributed_similarity(&f, &e, &soak_opts(strategy, pipeline, kind))
                        .unwrap();
                assert_eq!(
                    sim.as_slice(),
                    base.as_slice(),
                    "strategy {} pipeline {pipeline} transport {}: soak-recovered matrix diverged",
                    strategy.name(),
                    kind.name()
                );
                assert_eq!(rep.dead_ranks, vec![2, 5]);
                assert_eq!(rep.stats.len(), P - 2, "both victims must be excused from stats");
                assert!(rep.recovered_tasks > 0);
                // One detection record per dead rank, in detection order.
                let mut detected: Vec<usize> =
                    rep.health.detections.iter().map(|d| d.rank).collect();
                detected.sort_unstable();
                assert_eq!(detected, vec![2, 5], "transport {}", kind.name());
            }
        }
    }
}

/// Minimal task-granular app whose payload *is* its task list — every pair
/// reported exactly once is the sharpest possible probe of cascade
/// re-orphaning (a task first delegated to rank 5, which then dies at the
/// gather, must be re-delegated and still appear exactly once).
struct EdgeApp;

impl DistributedApp for EdgeApp {
    fn name(&self) -> &'static str {
        "edges"
    }

    fn elements(&self) -> usize {
        2 * P
    }

    fn make_block(&self, range: std::ops::Range<usize>) -> BlockData {
        BlockData::Rows(Matrix::zeros(range.len(), 4))
    }

    fn recoverable(&self) -> bool {
        true
    }

    fn run_recovery_task(&self, _ctx: &mut WorkerCtx, t: quorall::allpairs::PairTask) -> Payload {
        Payload::Edges(vec![(t.a, t.b, 1.0)])
    }

    fn run_worker(&self, ctx: &mut WorkerCtx) -> Option<Payload> {
        let tasks = std::mem::take(&mut ctx.tasks);
        let mut edges = Vec::new();
        for t in &tasks {
            if !ctx.begin_task(t) {
                return None;
            }
            if ctx.task_revoked(t) {
                // Stolen by an idle rank (QUORALL_STEAL=on lane): the thief
                // reports it; including it here would double-count the pair.
                continue;
            }
            edges.push((t.a, t.b, 1.0f32));
            ctx.complete_task(*t);
        }
        Some(Payload::Edges(edges))
    }
}

#[test]
fn multi_failure_soak_covers_every_pair_exactly_once() {
    for kind in [TransportKind::Memory, TransportKind::Tcp] {
        let rep = run_app(Arc::new(EdgeApp), &soak_opts(Strategy::Cyclic, false, kind)).unwrap();
        assert_eq!(rep.dead_ranks, vec![2, 5]);
        assert!(rep.recovered_tasks > 0);
        let mut seen: Vec<(usize, usize)> = Vec::new();
        for (rank, payload) in &rep.results {
            match payload {
                Payload::Edges(e) => seen.extend(e.iter().map(|&(a, b, _)| (a, b))),
                other => panic!("rank {rank}: wrong payload {}", other.kind()),
            }
        }
        seen.sort_unstable();
        let expect: Vec<(usize, usize)> =
            (0..P).flat_map(|a| (a..P).map(move |b| (a, b))).collect();
        assert_eq!(
            seen,
            expect,
            "transport {}: double failure must still cover all pairs exactly once",
            kind.name()
        );
    }
}

// ---- Failure-detector observability on the memory backend ----

#[test]
fn memory_backend_reports_injected_detections() {
    let mut rng = Rng::new(9);
    let f = Matrix::from_fn(45, 8, |_, _| rng.normal_f32());
    let e = exec();
    let mut o = opts(Strategy::Cyclic, false, false, TransportKind::Memory);
    o.redundancy = 2;
    o.recover = true;
    o.kill = vec![3];
    o.kill_at = KillAt::Compute { tasks: 1 };
    let (_, rep) = run_distributed_similarity(&f, &e, &o).unwrap();
    assert_eq!(rep.health.backend, "memory");
    assert_eq!(rep.health.detections.len(), 1);
    assert_eq!(rep.health.detections[0].rank, 3);
    assert_eq!(
        rep.health.detections[0].cause, "injected",
        "the memory backend has no wire: a kill flag is its only detector"
    );
    assert_eq!(rep.health.reconnect_attempts, 0);
}

// ---- Process mode: real OS processes joined over the wire ----

#[test]
fn tcp_process_mode_matches_memory_bitwise() {
    let mut rng = Rng::new(17);
    let f = Matrix::from_fn(32, 8, |_, _| rng.normal_f32());
    let e = exec();
    let mut base_opts = EngineOptions::new(4, Strategy::Cyclic);
    base_opts.transport = TransportKind::Memory;
    let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();

    let mut o = EngineOptions::new(4, Strategy::Cyclic);
    o.transport = TransportKind::Tcp;
    o.tcp_processes = true;
    // The test harness is not the CLI: point the launcher at the real
    // `quorall` binary Cargo built for this test run.
    o.worker_bin = Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_quorall")));
    let (sim, rep) = run_distributed_similarity(&f, &e, &o).unwrap();
    assert_eq!(
        sim.as_slice(),
        base.as_slice(),
        "process-mode similarity diverged from the in-memory run"
    );
    assert_eq!(rep.transport, TransportKind::Tcp);
    assert_eq!(rep.stats.len(), 4, "every worker process must report stats");
}
