//! Integration: mid-run crash recovery. A rank killed at any phase
//! (scatter / compute / gather) has its unfinished tasks re-assigned to
//! surviving quorum hosts via the leader's task ledger, and the recovered
//! output is **bitwise identical** to the failure-free run — the paper's
//! r-fold replication made operational, for every task-granular app, under
//! both placements with natural multi-host coverage and both transports.
//!
//! Run with `QUORALL_PIPELINE=on` and `=off` (CI does both): the ledger's
//! provenance tags only exist in pipelined mode, so the two runs exercise
//! different orphan sets (streamed prefix vs everything).

use quorall::allpairs::RedundantAssignment;
use quorall::apps::nbody::{run_distributed_nbody, Bodies};
use quorall::apps::similarity::run_distributed_similarity;
use quorall::apps::{DistMode, PcitApp};
use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{
    run_app, run_resilient_pcit_at, run_single_node, BlockData, DegradeMode, DistributedApp,
    EngineOptions, KillAt, Payload, TransportKind, WorkerCtx,
};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::pcit::standardize_rows;
use quorall::quorum::Strategy;
use quorall::runtime::{Executor, NativeBackend};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::sync::Arc;

fn exec() -> Executor {
    Arc::new(NativeBackend::new())
}

fn dataset(genes: usize) -> ExpressionDataset {
    ExpressionDataset::generate(SyntheticSpec {
        genes,
        samples: 24,
        modules: 5,
        noise: 0.5,
        seed: 77,
    })
}

/// Kill phases under test: before any work, after one completed task, and
/// after all compute but before the final Result.
const KILL_PHASES: [KillAt; 3] =
    [KillAt::Scatter, KillAt::Compute { tasks: 1 }, KillAt::Gather];

/// Placements with >= 2 hosts for every pair at P = 9: the cyclic r-fold
/// cover and the 3×3 grid's natural row∪column coverage.
const STRATEGIES: [Strategy; 2] = [Strategy::Cyclic, Strategy::Grid];

const P: usize = 9;
const VICTIM: usize = 4;

fn recovery_opts(strategy: Strategy, pipeline: bool) -> EngineOptions {
    let mut opts = EngineOptions::new(P, strategy);
    opts.pipeline = pipeline;
    opts.redundancy = 2;
    opts.recover = true;
    opts
}

// ---- Similarity: bitwise matrix parity across the full kill matrix ----

#[test]
fn similarity_recovery_bitwise_identical() {
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let base_opts = recovery_opts(strategy, pipeline);
            let (base, base_rep) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
            assert!(base_rep.dead_ranks.is_empty());
            for kill_at in KILL_PHASES {
                let mut opts = recovery_opts(strategy, pipeline);
                opts.kill = vec![VICTIM];
                opts.kill_at = kill_at;
                let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
                assert_eq!(
                    sim.as_slice(),
                    base.as_slice(),
                    "strategy {} pipeline {pipeline} kill_at {}: recovered matrix differs",
                    strategy.name(),
                    kill_at.name()
                );
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
                assert_eq!(rep.stats.len(), P - 1, "dead rank must not report stats");
            }
        }
    }
}

// ---- N-body: bitwise force parity (f64 reduce order preserved) ----

#[test]
fn nbody_recovery_bitwise_identical() {
    let b = Bodies::random(54, 7);
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let base_opts = recovery_opts(strategy, pipeline);
            let (base, _) = run_distributed_nbody(&b, &base_opts).unwrap();
            for kill_at in KILL_PHASES {
                let mut opts = recovery_opts(strategy, pipeline);
                opts.kill = vec![VICTIM];
                opts.kill_at = kill_at;
                let (forces, rep) = run_distributed_nbody(&b, &opts).unwrap();
                for i in 0..b.n {
                    assert_eq!(
                        forces[i],
                        base[i],
                        "strategy {} pipeline {pipeline} kill_at {}: body {i} forces differ",
                        strategy.name(),
                        kill_at.name()
                    );
                }
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
            }
        }
    }
}

// ---- PCIT (quorum-local, threshold mode = pairwise-exact) ----

fn pcit_cfg(strategy: Strategy, pipeline: bool) -> RunConfig {
    RunConfig {
        ranks: P,
        mode: PcitMode::QuorumLocal,
        strategy,
        pipeline,
        use_pcit_significance: false, // threshold mode: pairwise-exact
        threshold: 0.5,
        ..RunConfig::default()
    }
}

#[test]
fn pcit_recovery_bitwise_identical() {
    let d = dataset(90);
    let single = run_single_node(&d, 2, Some(0.5));
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let cfg = pcit_cfg(strategy, pipeline);
            let base =
                run_resilient_pcit_at(&cfg, &d, exec(), 2, &[], KillAt::Scatter).unwrap();
            assert!(base.network.same_edges(&single.network));
            for kill_at in KILL_PHASES {
                let rep =
                    run_resilient_pcit_at(&cfg, &d, exec(), 2, &[VICTIM], kill_at).unwrap();
                assert_eq!(
                    rep.network.edges,
                    base.network.edges,
                    "strategy {} pipeline {pipeline} kill_at {}: recovered network differs",
                    strategy.name(),
                    kill_at.name()
                );
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
                if kill_at == KillAt::Scatter {
                    assert!(rep.recovered_tasks > 0, "scatter kill loses every task");
                }
            }
        }
    }
}

// ---- Streamed scatter: the kill matrix must stay bitwise identical ----

#[test]
fn streamed_scatter_similarity_recovery_bitwise_identical() {
    // Under the streamed scatter a `--kill-at scatter` death strikes while
    // blocks are still in flight; the leader masks it by re-assigning the
    // victim's tasks to backup owners whose own block streams already
    // carry everything needed — no re-streaming, and the matrix must stay
    // bitwise identical to the failure-free *monolithic* run (one compare
    // covers both scatter-mode parity and recovery parity).
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    let (base, _) = run_distributed_similarity(&f, &e, &{
        let mut o = recovery_opts(Strategy::Cyclic, false);
        o.streamed_scatter = false;
        o
    })
    .unwrap();
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            for kill_at in KILL_PHASES {
                let mut opts = recovery_opts(strategy, pipeline);
                opts.streamed_scatter = true;
                opts.kill = vec![VICTIM];
                opts.kill_at = kill_at;
                let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
                assert_eq!(
                    sim.as_slice(),
                    base.as_slice(),
                    "strategy {} pipeline {pipeline} kill_at {}: streamed-scatter recovered matrix differs",
                    strategy.name(),
                    kill_at.name()
                );
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
                assert_eq!(rep.stats.len(), P - 1, "dead rank must not report stats");
                // A delivery lost to the freshly-killed victim must not eat
                // a block's one-time payload accounting: every one of the
                // N×dim f32s still ships (with its `first` flag) to some
                // surviving replica.
                assert!(
                    rep.scatter_comm_bytes >= (54 * 12 * 4) as u64,
                    "kill_at {}: scatter bytes {} lost a block's payload",
                    kill_at.name(),
                    rep.scatter_comm_bytes
                );
            }
        }
    }
}

#[test]
fn streamed_scatter_pcit_recovery_bitwise_identical() {
    // Same matrix for threshold-mode quorum-local PCIT, against the
    // failure-free monolithic network.
    let d = dataset(90);
    let mut base_cfg = pcit_cfg(Strategy::Cyclic, false);
    base_cfg.streamed_scatter = false;
    let base = run_resilient_pcit_at(&base_cfg, &d, exec(), 2, &[], KillAt::Scatter).unwrap();
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let mut cfg = pcit_cfg(strategy, pipeline);
            cfg.streamed_scatter = true;
            for kill_at in KILL_PHASES {
                let rep =
                    run_resilient_pcit_at(&cfg, &d, exec(), 2, &[VICTIM], kill_at).unwrap();
                assert_eq!(
                    rep.network.edges,
                    base.network.edges,
                    "strategy {} pipeline {pipeline} kill_at {}: streamed-scatter recovered network differs",
                    strategy.name(),
                    kill_at.name()
                );
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
            }
        }
    }
}

#[test]
fn streamed_scatter_nbody_scatter_kill_bitwise_identical() {
    // F64 reduce order must survive a scatter-phase death under the
    // streamed scatter (the recovered partials splice in the dead rank's
    // original task order).
    let b = Bodies::random(54, 7);
    let (base, _) = run_distributed_nbody(&b, &{
        let mut o = recovery_opts(Strategy::Cyclic, false);
        o.streamed_scatter = false;
        o
    })
    .unwrap();
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let mut opts = recovery_opts(strategy, pipeline);
            opts.streamed_scatter = true;
            opts.kill = vec![VICTIM];
            opts.kill_at = KillAt::Scatter;
            let (forces, rep) = run_distributed_nbody(&b, &opts).unwrap();
            for i in 0..b.n {
                assert_eq!(
                    forces[i],
                    base[i],
                    "strategy {} pipeline {pipeline} body {i}: streamed-scatter recovered forces differ",
                    strategy.name()
                );
            }
            assert_eq!(rep.dead_ranks, vec![VICTIM]);
            assert!(rep.recovered_tasks > 0, "scatter kill loses every task");
        }
    }
}

// ---- Mid-compute kill orphans only the unreported suffix (pipelined) ----

#[test]
fn pipelined_ledger_limits_orphans_to_unreported_tasks() {
    // With streaming on, a rank killed after completing (and streaming) k
    // tasks must only have its *remaining* tasks recomputed — the ledger's
    // provenance folding at work. The victim's first task was streamed, so
    // recovered_tasks < its full task count.
    let mut rng = Rng::new(11);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    // Work stealing would drain the victim's queue through a different
    // channel and make the orphan count timing-dependent — pin it off so
    // the ledger arithmetic below stays exact.
    let full = {
        let mut opts = recovery_opts(Strategy::Cyclic, true);
        opts.steal = false;
        opts.kill = vec![VICTIM];
        opts.kill_at = KillAt::Scatter;
        let (_, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        rep.recovered_tasks
    };
    assert!(full > 1, "victim needs >= 2 tasks for this test (got {full})");
    let mut opts = recovery_opts(Strategy::Cyclic, true);
    opts.steal = false;
    opts.kill = vec![VICTIM];
    opts.kill_at = KillAt::Compute { tasks: 1 };
    let (_, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
    assert_eq!(
        rep.recovered_tasks,
        full - 1,
        "one streamed task must be excused from recovery"
    );
}

// ---- Ineffective injection is rejected, not silently ignored ----

#[test]
fn impossible_compute_kill_rejected() {
    // compute:50 can never fire at P = 9 (each rank owns ~5 tasks); the
    // engine must reject it instead of running a no-op injection while
    // still treating the victim as doomed for assignee selection.
    let mut rng = Rng::new(9);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    let mut opts = recovery_opts(Strategy::Cyclic, false);
    opts.kill = vec![VICTIM];
    opts.kill_at = KillAt::Compute { tasks: 50 };
    let err = run_distributed_similarity(&f, &e, &opts).unwrap_err();
    assert!(
        format!("{err:#}").contains("can never fire"),
        "unexpected error: {err:#}"
    );
}

// ---- Insufficient redundancy still aborts with a clean error ----

#[test]
fn insufficient_redundancy_aborts_cleanly() {
    // r = 1 leaves each pair with a single owner: killing one that owns
    // work is unrecoverable and must be rejected up front.
    let mut rng = Rng::new(3);
    let f = Matrix::from_fn(40, 8, |_, _| rng.normal_f32());
    let e = exec();
    let mut opts = EngineOptions::new(7, Strategy::Cyclic);
    opts.redundancy = 1;
    opts.recover = true;
    opts.kill = vec![0];
    let err = run_distributed_similarity(&f, &e, &opts).unwrap_err();
    assert!(
        format!("{err:#}").contains("insufficient redundancy"),
        "unexpected error: {err:#}"
    );
}

// ---- Barrier-phase apps are accepted and recover ----

/// A task-granular app *with* a sync phase: proves the engine no longer
/// categorically rejects barrier-phase apps for resilient runs — the old
/// "barrier-free apps only" restriction is gone. Survivors stash the late
/// task grant at the barrier and serve it after their own result.
struct PhasedApp;

impl DistributedApp for PhasedApp {
    fn name(&self) -> &'static str {
        "phased"
    }

    fn elements(&self) -> usize {
        2 * P
    }

    fn make_block(&self, range: std::ops::Range<usize>) -> BlockData {
        BlockData::Rows(Matrix::zeros(range.len(), 4))
    }

    fn sync_phases(&self) -> Vec<u8> {
        vec![1]
    }

    fn recoverable(&self) -> bool {
        true
    }

    fn run_recovery_task(&self, _ctx: &mut WorkerCtx, t: quorall::allpairs::PairTask) -> Payload {
        Payload::Edges(vec![(t.a, t.b, 1.0)])
    }

    fn run_worker(&self, ctx: &mut WorkerCtx) -> Option<Payload> {
        let tasks = std::mem::take(&mut ctx.tasks);
        let mut edges = Vec::new();
        for t in &tasks {
            if !ctx.begin_task(t) {
                return None;
            }
            if ctx.task_revoked(t) {
                // Stolen by an idle rank (QUORALL_STEAL=on lane): the thief
                // reports it; including it here would double-count the pair.
                continue;
            }
            edges.push((t.a, t.b, 1.0f32));
            ctx.complete_task(*t);
        }
        ctx.phase_done(1);
        if !ctx.barrier() {
            return None;
        }
        Some(Payload::Edges(edges))
    }
}

#[test]
fn barrier_phase_app_recovers_mid_run() {
    let mut opts = recovery_opts(Strategy::Cyclic, false);
    opts.kill = vec![VICTIM];
    opts.kill_at = KillAt::Compute { tasks: 1 };
    let rep = run_app(Arc::new(PhasedApp), &opts).unwrap();
    assert_eq!(rep.dead_ranks, vec![VICTIM]);
    assert!(rep.recovered_tasks > 0);
    // Every pair task reported exactly once across all per-rank payloads.
    let mut seen: Vec<(usize, usize)> = Vec::new();
    for (rank, payload) in &rep.results {
        match payload {
            Payload::Edges(e) => seen.extend(e.iter().map(|&(a, b, _)| (a, b))),
            other => panic!("rank {rank}: wrong payload {}", other.kind()),
        }
    }
    seen.sort_unstable();
    let expect: Vec<(usize, usize)> = (0..P)
        .flat_map(|a| (a..P).map(move |b| (a, b)))
        .collect();
    assert_eq!(seen, expect, "recovered run must cover all pairs exactly once");
}

// ---- Exact-mode PCIT: ring re-routing around a dead rank ----

fn exact_cfg(strategy: Strategy, pipeline: bool) -> RunConfig {
    RunConfig {
        ranks: P,
        mode: PcitMode::QuorumExact,
        strategy,
        pipeline,
        ..RunConfig::default()
    }
}

#[test]
fn exact_pcit_kill_matrix_bitwise_identical() {
    // A mid-ring death no longer aborts exact mode: the leader recomputes
    // the ring successor map around the dead rank, a substitute (which
    // holds the victim's row blocks under r-fold placement) replays its
    // tile production and elimination tasks in the original per-pair FIFO
    // order, and the spliced network is bitwise-identical to the
    // failure-free run — across both placements, both protocols, and
    // every kill phase.
    let d = dataset(90);
    let single = run_single_node(&d, 2, None);
    for strategy in STRATEGIES {
        for pipeline in [false, true] {
            let cfg = exact_cfg(strategy, pipeline);
            let base = run_resilient_pcit_at(&cfg, &d, exec(), 2, &[], KillAt::Scatter).unwrap();
            assert!(
                base.network.same_edges(&single.network),
                "strategy {} pipeline {pipeline}: failure-free exact run drifted from single node",
                strategy.name()
            );
            for kill_at in KILL_PHASES {
                let rep =
                    run_resilient_pcit_at(&cfg, &d, exec(), 2, &[VICTIM], kill_at).unwrap();
                assert_eq!(
                    rep.network.edges,
                    base.network.edges,
                    "strategy {} pipeline {pipeline} kill_at {}: ring-recovered network differs",
                    strategy.name(),
                    kill_at.name()
                );
                assert_eq!(rep.dead_ranks, vec![VICTIM]);
                if kill_at == KillAt::Gather {
                    // Post-barrier death: the victim finished its ring scan,
                    // so recovery replays its result tasks off the ledger —
                    // no re-route order is ever issued.
                    assert_eq!(
                        rep.ring_reroutes, 0,
                        "strategy {} pipeline {pipeline}: gather death must not re-route",
                        strategy.name()
                    );
                } else {
                    assert!(
                        rep.ring_reroutes >= 1,
                        "strategy {} pipeline {pipeline} kill_at {}: a pre-barrier death must re-route the ring",
                        strategy.name(),
                        kill_at.name()
                    );
                }
            }
        }
    }
}

#[test]
fn exact_pcit_mid_compute_death_recovers_via_run_app() {
    // The raw `run_app` surface (what the old abort test used) now rides
    // the same ring recovery: bitwise-equal per-rank payloads.
    let d = dataset(90);
    let app = || {
        Arc::new(PcitApp::new(
            standardize_rows(&d.expr),
            exec(),
            DistMode::Exact,
            true,
            0.85,
        ))
    };
    let base = run_app(app(), &recovery_opts(Strategy::Cyclic, false)).unwrap();
    let mut opts = recovery_opts(Strategy::Cyclic, false);
    opts.kill = vec![VICTIM];
    opts.kill_at = KillAt::Compute { tasks: 1 };
    let rep = run_app(app(), &opts).unwrap();
    assert_eq!(rep.dead_ranks, vec![VICTIM]);
    assert!(rep.ring_reroutes >= 1, "mid-compute death must re-route the ring");
    assert_eq!(
        edges_by_rank(&rep.results),
        edges_by_rank(&base.results),
        "ring-recovered per-rank payloads must match the failure-free run bitwise"
    );
}

// ---- Full-PCIT local mode recovers (approximately, like the ablation) ----

#[test]
fn full_pcit_local_mode_recovers_close_to_single() {
    let d = dataset(80);
    let single = run_single_node(&d, 2, None);
    let cfg = RunConfig {
        ranks: 8,
        mode: PcitMode::QuorumLocal,
        use_pcit_significance: true,
        ..RunConfig::default()
    };
    let rep = run_resilient_pcit_at(&cfg, &d, exec(), 2, &[3], KillAt::Compute { tasks: 1 })
        .unwrap();
    let j = rep.network.jaccard(&single.network);
    assert!(j > 0.4, "jaccard {j}");
    assert_eq!(rep.dead_ranks, vec![3]);
}

// ---- Work stealing × failure injection ----
//
// The steal scheduler re-grants a slow rank's queued tasks to idle ranks
// that already hold the blocks (zero extra scatter traffic). These tests
// pin down its composition with the kill matrix: a victim that dies after
// being stolen from, a thief that dies holding stolen grants, and stealing
// under the streamed scatter — all bitwise-identical to the failure-free
// static run.

/// Total pair tasks at P = 9 (self-pairs included): P(P+1)/2.
const TOTAL_TASKS: u64 = (P * (P + 1) / 2) as u64;

#[test]
fn stealing_drains_throttled_rank_bitwise_identical() {
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    // Static unthrottled baseline: the parity target.
    let mut base_opts = recovery_opts(Strategy::Cyclic, false);
    base_opts.steal = false;
    let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
    for pipeline in [false, true] {
        let mut opts = recovery_opts(Strategy::Cyclic, pipeline);
        opts.steal = true;
        opts.steal_batch = 2;
        opts.throttle = Some((VICTIM, 200));
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: stolen-task splice changed bits"
        );
        assert!(
            rep.stolen_tasks > 0,
            "pipeline {pipeline}: a 200x-throttled rank must get stolen from"
        );
        assert!(rep.steal_latency_secs >= 0.0);
        assert!(rep.dead_ranks.is_empty());
        // Per-rank execution skew (satellite): every task ran somewhere.
        // Stolen tasks execute through the recovery path and are not in
        // the per-rank own-queue counters, so the sum may fall short of
        // the pair count by at most the stolen count (and a task whose
        // revocation lost the race may be counted by its original owner).
        let executed: u64 = rep.stats.iter().map(|s| s.tasks_executed).sum();
        assert!(
            executed + rep.stolen_tasks >= TOTAL_TASKS && executed <= TOTAL_TASKS,
            "pipeline {pipeline}: {executed} executed + {} stolen vs {TOTAL_TASKS} tasks",
            rep.stolen_tasks
        );
        for s in &rep.stats {
            if s.tasks_executed > 0 {
                assert!(s.task_exec_min_secs <= s.task_exec_max_secs);
                assert!(s.task_exec_total_secs >= s.task_exec_max_secs);
            }
        }
    }
}

#[test]
fn steal_victim_death_bitwise_identical() {
    // The throttled rank gets stolen from, then dies. Its stolen-away
    // tasks are already delegated (the thieves keep them); only the
    // remainder re-orphans through the ledger — and the splice must still
    // be bitwise-perfect.
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    let mut base_opts = recovery_opts(Strategy::Cyclic, false);
    base_opts.steal = false;
    let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
    for pipeline in [false, true] {
        let mut opts = recovery_opts(Strategy::Cyclic, pipeline);
        opts.steal = true;
        opts.steal_batch = 2;
        opts.throttle = Some((VICTIM, 200));
        opts.kill = vec![VICTIM];
        opts.kill_at = KillAt::Compute { tasks: 2 };
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: post-steal death recovery changed bits"
        );
        assert_eq!(rep.dead_ranks, vec![VICTIM]);
        assert_eq!(rep.stats.len(), P - 1, "dead rank must not report stats");
        assert!(
            rep.stolen_tasks > 0,
            "pipeline {pipeline}: the victim sleeps ~200 task-times before \
             its second task — the idle ranks must steal its tail first"
        );
    }
}

#[test]
fn steal_kill_with_tile_pool_bitwise_identical() {
    // The per-rank tile pool rides the same task loop the steal scheduler
    // and the recovery ledger drive: revocation still lands only at task
    // boundaries, so a thief's pooled recompute and the post-death splice
    // must match the static, unthrottled, single-threaded run bit for bit.
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    let mut base_opts = recovery_opts(Strategy::Cyclic, false);
    base_opts.steal = false;
    base_opts.threads_per_rank = 1;
    let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
    for pipeline in [false, true] {
        let mut opts = recovery_opts(Strategy::Cyclic, pipeline);
        opts.steal = true;
        opts.steal_batch = 2;
        opts.throttle = Some((VICTIM, 200));
        opts.kill = vec![VICTIM];
        opts.kill_at = KillAt::Compute { tasks: 2 };
        opts.threads_per_rank = 4;
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: pooled steal + death recovery changed bits"
        );
        assert_eq!(rep.dead_ranks, vec![VICTIM]);
        assert!(
            rep.stolen_tasks > 0,
            "pipeline {pipeline}: the throttled victim must get stolen from before dying"
        );
    }
}

#[test]
fn steal_thief_death_reorphans_through_cascade() {
    // Grid placement at P = 9: a generic block pair (different row and
    // column) has exactly two hosts, so a two-host tail task in the
    // throttled victim's queue can only ever be granted to its one co-host
    // — which makes the thief deterministic. Arm that thief with
    // `compute:<its own task count>`: the trigger is unreachable from its
    // own queue (the last own-task check sees count-1) and first fires at
    // the check before its first stolen task, i.e. exactly when it holds a
    // stolen grant. The grant must then re-orphan through the cascade.
    let quorum = Strategy::Grid.build_redundant(P, 2).unwrap();
    let assign = RedundantAssignment::build(quorum.as_ref(), 2);
    // Pick a victim whose queue *tail* holds a two-host task. Index >= 2:
    // the scheduler never revokes the task a rank is computing (nor the
    // one the stale ledger still thinks it is), so only the tail from the
    // third slot on is reliably stealable.
    let (victim, t_star) = (0..P)
        .find_map(|v| {
            let vt = assign.primary_tasks_for(v);
            if vt.len() < 4 {
                return None;
            }
            vt[2..]
                .iter()
                .rev()
                .find(|t| quorum.pair_hosts(t.a, t.b).len() == 2)
                .map(|t| (v, *t))
        })
        .expect("some rank must own a two-host tail task under the 3x3 grid");
    let thief = *quorum
        .pair_hosts(t_star.a, t_star.b)
        .iter()
        .find(|&&h| h != victim)
        .unwrap();
    let own = assign.primary_tasks_for(thief).len();

    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    for pipeline in [false, true] {
        let mut base_opts = recovery_opts(Strategy::Grid, pipeline);
        base_opts.steal = false;
        let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();

        let mut opts = recovery_opts(Strategy::Grid, pipeline);
        opts.steal = true;
        opts.steal_batch = 2;
        opts.throttle = Some((victim, 300));
        opts.kill = vec![thief];
        opts.kill_at = KillAt::Compute { tasks: own };
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: thief-death re-orphaning changed bits"
        );
        assert_eq!(
            rep.dead_ranks,
            vec![thief],
            "pipeline {pipeline}: the kill trigger needs a stolen grant to fire"
        );
        assert!(rep.stolen_tasks > 0);
    }
}

#[test]
fn steal_composes_with_streamed_scatter_and_recovery() {
    // Stealing while blocks are still streaming and a third rank dies at
    // scatter: thief eligibility comes from the placement, so a granted
    // task may have to wait on the thief's in-flight block stream — and
    // the result must still match the failure-free monolithic static run.
    const SCATTER_VICTIM: usize = 1;
    let mut rng = Rng::new(5);
    let f = Matrix::from_fn(54, 12, |_, _| rng.normal_f32());
    let e = exec();
    let mut base_opts = recovery_opts(Strategy::Cyclic, false);
    base_opts.steal = false;
    base_opts.streamed_scatter = false;
    let (base, _) = run_distributed_similarity(&f, &e, &base_opts).unwrap();
    for pipeline in [false, true] {
        let mut opts = recovery_opts(Strategy::Cyclic, pipeline);
        opts.streamed_scatter = true;
        opts.steal = true;
        opts.steal_batch = 2;
        opts.throttle = Some((VICTIM, 200));
        opts.kill = vec![SCATTER_VICTIM];
        opts.kill_at = KillAt::Scatter;
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        assert_eq!(
            sim.as_slice(),
            base.as_slice(),
            "pipeline {pipeline}: steal under streamed scatter changed bits"
        );
        assert_eq!(rep.dead_ranks, vec![SCATTER_VICTIM]);
        assert!(
            rep.stolen_tasks > 0,
            "pipeline {pipeline}: the throttled rank must still get stolen from"
        );
    }
}

// ---- Edge-payload helpers: output identity at pair granularity ----

/// Per-rank edge payloads in rank order — payload-level bitwise identity.
fn edges_by_rank(results: &[(usize, Payload)]) -> Vec<(usize, Vec<(usize, usize, f32)>)> {
    let mut v: Vec<(usize, Vec<(usize, usize, f32)>)> = results
        .iter()
        .map(|(rank, payload)| match payload {
            Payload::Edges(e) => (*rank, e.clone()),
            other => panic!("rank {rank}: wrong payload {}", other.kind()),
        })
        .collect();
    v.sort_by_key(|(rank, _)| *rank);
    v
}

/// All pairs reported across every per-rank payload, sorted.
fn collect_pairs(results: &[(usize, Payload)]) -> Vec<(usize, usize)> {
    let mut seen: Vec<(usize, usize)> = Vec::new();
    for (rank, payload) in results {
        match payload {
            Payload::Edges(e) => seen.extend(e.iter().map(|&(a, b, _)| (a, b))),
            other => panic!("rank {rank}: wrong payload {}", other.kind()),
        }
    }
    seen.sort_unstable();
    seen
}

fn all_pairs() -> Vec<(usize, usize)> {
    (0..P).flat_map(|a| (a..P).map(move |b| (a, b))).collect()
}

/// Edge-payload app with tunable stalls — the deterministic clockwork for
/// the rejoin and cascade tests. Every recovery grant sleeps
/// `recovery_ms` at its assignee, which pins the leader in its gather
/// loop (recovery pending) long enough for a timed event — a rejoin
/// window expiring, a second injected death — to land *while* the
/// reassignment is still in flight; `slow_rank` stretches one rank's own
/// queue by `own_ms` per task the same way. The payload is the task list
/// itself, so exactly-once pair coverage and bitwise parity collapse into
/// one assertion. Honors the mid-run `per_task_results()` flip (prefix
/// flush, then per-task chunks) like the in-tree apps — a detected rejoin
/// requires it.
struct StallApp {
    /// Rank whose own tasks each sleep `own_ms` (`usize::MAX` = nobody).
    slow_rank: usize,
    own_ms: u64,
    recovery_ms: u64,
}

impl DistributedApp for StallApp {
    fn name(&self) -> &'static str {
        "stall-edges"
    }

    fn elements(&self) -> usize {
        2 * P
    }

    fn make_block(&self, range: std::ops::Range<usize>) -> BlockData {
        BlockData::Rows(Matrix::zeros(range.len(), 4))
    }

    fn recoverable(&self) -> bool {
        true
    }

    fn run_recovery_task(&self, _ctx: &mut WorkerCtx, t: quorall::allpairs::PairTask) -> Payload {
        std::thread::sleep(std::time::Duration::from_millis(self.recovery_ms));
        Payload::Edges(vec![(t.a, t.b, 1.0)])
    }

    fn run_worker(&self, ctx: &mut WorkerCtx) -> Option<Payload> {
        let tasks = std::mem::take(&mut ctx.tasks);
        let streams_from_start = ctx.per_task_results();
        let mut prefix_flushed = false;
        let mut edges = Vec::new();
        for t in &tasks {
            if !ctx.begin_task(t) {
                return None;
            }
            // A rejoin inside `begin_task` flips per-task streaming on:
            // flush the accumulated prefix as one tagged chunk first.
            if !streams_from_start && !prefix_flushed && ctx.per_task_results() {
                prefix_flushed = true;
                let prefix = std::mem::take(&mut edges);
                ctx.stream_result(Payload::Edges(prefix));
            }
            if ctx.task_revoked(t) {
                continue;
            }
            if ctx.my_block == self.slow_rank {
                std::thread::sleep(std::time::Duration::from_millis(self.own_ms));
            }
            edges.push((t.a, t.b, 1.0f32));
            ctx.complete_task(*t);
            if streams_from_start || prefix_flushed {
                let chunk = std::mem::take(&mut edges);
                ctx.stream_result(Payload::Edges(chunk));
            }
        }
        Some(Payload::Edges(edges))
    }
}

// ---- Worker rejoin: transient disconnect, overlap cancellation ----

/// The rejoin clockwork: the victim goes dark for 100 ms — long past the
/// leader's 25 ms failure poll, so detection and reassignment are certain
/// — while every recovery grant sleeps 400 ms at its assignee, so the
/// leader is certainly still mid-recovery when the Rejoin lands and the
/// overlap cancellation has a 300 ms cushion to win every race.
fn rejoin_app() -> Arc<StallApp> {
    Arc::new(StallApp { slow_rank: usize::MAX, own_ms: 0, recovery_ms: 400 })
}

fn rejoin_opts(tasks_before_dark: usize) -> EngineOptions {
    let mut opts = recovery_opts(Strategy::Cyclic, false);
    // The duplicate/recovered counts below are exact; the steal scheduler
    // (QUORALL_STEAL=on lane) would add benign-but-nondeterministic
    // re-grants, so pin it off — steal × kill composition has its own
    // suite above.
    opts.steal = false;
    opts.kill = vec![VICTIM];
    opts.kill_at = KillAt::Disconnect { tasks: tasks_before_dark };
    opts.rejoin_after_ms = Some(100);
    opts
}

fn no_steal_opts(strategy: Strategy) -> EngineOptions {
    let mut opts = recovery_opts(strategy, false);
    opts.steal = false;
    opts
}

fn assert_rejoin_run(rep_tag: &str, tasks_before_dark: usize) {
    let base = run_app(rejoin_app(), &no_steal_opts(Strategy::Cyclic)).unwrap();
    let rep = run_app(rejoin_app(), &rejoin_opts(tasks_before_dark)).unwrap();
    assert_eq!(
        rep.dead_ranks,
        vec![VICTIM],
        "{rep_tag}: a 100 ms dark window must outlive the failure poll"
    );
    assert_eq!(rep.rejoined_ranks, vec![VICTIM], "{rep_tag}: the comeback must be recorded");
    assert_eq!(
        collect_pairs(&rep.results),
        all_pairs(),
        "{rep_tag}: every pair exactly once — no duplicates from the cancelled overlap"
    );
    assert_eq!(
        edges_by_rank(&rep.results),
        edges_by_rank(&base.results),
        "{rep_tag}: rejoined run must match the failure-free run bitwise"
    );
    assert_eq!(
        rep.duplicate_results, 0,
        "{rep_tag}: the cancellation must win — no assignee result should land"
    );
    assert_eq!(rep.stats.len(), P, "{rep_tag}: a rejoined rank reports stats again");
    assert!(rep.uncovered_pairs.is_empty());
    assert_eq!(rep.coverage_ratio, 1.0);
}

#[test]
fn rejoin_during_compute_cancels_reassignment_overlap() {
    // Dark after one completed task: the resume cursor names it, the
    // leader prunes it from the orphan ledger, cancels the in-flight
    // reassignment of the remainder, and takes the rest from the
    // rejoiner's own per-task chunks (prefix-flush chunk leading).
    assert_rejoin_run("rejoin mid-compute", 1);
}

#[test]
fn rejoin_during_scatter_resumes_full_queue() {
    // Dark before completing anything: the resume cursor is empty, every
    // task re-orphans, and the rejoiner reclaims its entire queue from
    // the cancelled reassignment (its prefix-flush chunk is empty).
    // Under the streamed-scatter lane this also exercises the rejoin
    // block re-ship: the leader abandoned the victim's block queue at
    // the death, so without the re-ship the rejoiner would wait in
    // `ensure_blocks` forever.
    assert_rejoin_run("rejoin at scatter", 0);
}

#[test]
fn rejoin_after_recovery_finished_is_superseded() {
    // With instant recovery grants, the 100 ms dark window is long enough
    // that every orphan is recovered and spliced before the victim comes
    // back. The rejoiner's whole stream must be revoked/superseded — and
    // the output still bitwise-identical with exactly-once coverage.
    let app = || Arc::new(StallApp { slow_rank: usize::MAX, own_ms: 0, recovery_ms: 0 });
    let base = run_app(app(), &no_steal_opts(Strategy::Cyclic)).unwrap();
    let rep = run_app(app(), &rejoin_opts(1)).unwrap();
    assert_eq!(rep.dead_ranks, vec![VICTIM]);
    assert_eq!(rep.rejoined_ranks, vec![VICTIM]);
    assert_eq!(collect_pairs(&rep.results), all_pairs());
    assert_eq!(edges_by_rank(&rep.results), edges_by_rank(&base.results));
    assert!(
        rep.recovered_tasks > 0,
        "instant grants must finish recovery inside the dark window"
    );
}

// ---- Cascading failure: second death while Reassign is in flight ----

#[test]
fn cascade_second_death_while_reassign_in_flight() {
    // Rank v1 dies after one task; its orphans are granted to survivors
    // whose recovery tasks each sleep 350 ms — so those Reassigns are
    // still in flight when rank w (own queue stretched 40 ms per task)
    // dies at the gather ~200 ms in. The leader must absorb the second
    // death mid-recovery — re-orphan w's whole queue to the remaining
    // survivors — and still deliver every pair exactly once, bitwise
    // equal to the failure-free run, on both placements and transports.
    for (strategy, r) in [(Strategy::Cyclic, 3), (Strategy::Grid, 2)] {
        let quorum = strategy.build_redundant(P, r).unwrap();
        let assign = RedundantAssignment::build(quorum.as_ref(), r);
        // Victim pair (v1, w) such that every pair keeps a surviving host
        // outside both — r = 3 guarantees it for cyclic; the grid's
        // 2-host generic pairs need a same-line victim pair, so search.
        let (v1, w) = (0..P)
            .flat_map(|a| (0..P).filter(move |&b| b != a).map(move |b| (a, b)))
            .find(|&(a, b)| {
                (0..P).flat_map(|x| (x..P).map(move |y| (x, y))).all(|(x, y)| {
                    quorum.pair_hosts(x, y).iter().any(|&h| h != a && h != b)
                })
            })
            .expect("some victim pair must leave every pair a surviving host");
        let orphaned =
            (assign.primary_tasks_for(v1).len() + assign.primary_tasks_for(w).len()) as u64;
        let app = || Arc::new(StallApp { slow_rank: w, own_ms: 40, recovery_ms: 350 });
        let mut base_opts = no_steal_opts(strategy);
        base_opts.redundancy = r;
        let base = run_app(app(), &base_opts).unwrap();
        for kind in [TransportKind::Memory, TransportKind::Tcp] {
            let mut opts = no_steal_opts(strategy);
            opts.redundancy = r;
            opts.transport = kind;
            opts.kill = vec![v1, w];
            opts.kill_at_list = vec![KillAt::Compute { tasks: 1 }, KillAt::Gather];
            let rep = run_app(app(), &opts).unwrap();
            let mut want_dead = vec![v1, w];
            want_dead.sort_unstable();
            assert_eq!(
                rep.dead_ranks,
                want_dead,
                "strategy {} transport {}: both victims must be detected",
                strategy.name(),
                kind.name()
            );
            assert_eq!(
                collect_pairs(&rep.results),
                all_pairs(),
                "strategy {} transport {}: cascade must keep coverage exactly-once",
                strategy.name(),
                kind.name()
            );
            assert_eq!(
                edges_by_rank(&rep.results),
                edges_by_rank(&base.results),
                "strategy {} transport {}: cascade-recovered payloads must match bitwise",
                strategy.name(),
                kind.name()
            );
            // Sync mode reports nothing before the final Result, so both
            // victims orphan their full queues — v1's through the first
            // Reassign wave, w's re-orphaned through the cascade.
            assert_eq!(
                rep.recovered_tasks,
                orphaned,
                "strategy {} transport {}: every orphan recovered exactly once",
                strategy.name(),
                kind.name()
            );
            assert_eq!(rep.stats.len(), P - 2);
            let mut detected: Vec<usize> =
                rep.health.detections.iter().map(|d| d.rank).collect();
            detected.sort_unstable();
            assert_eq!(detected, want_dead, "transport {}", kind.name());
        }
    }
}

// ---- Graceful degradation: redundancy exhausted, run completes ----

#[test]
fn degrade_partial_reports_uncovered_pairs() {
    // r = 1: rank 0's death leaves some pairs with no surviving host.
    // Under `--degrade partial` the run completes every coverable task
    // and reports the rest in the manifest instead of aborting (the
    // default abort flavor is pinned by
    // `insufficient_redundancy_aborts_cleanly`).
    let mut opts = EngineOptions::new(P, Strategy::Cyclic);
    opts.steal = false;
    opts.redundancy = 1;
    opts.recover = true;
    opts.kill = vec![0];
    opts.kill_at = KillAt::Compute { tasks: 1 };
    opts.degrade = DegradeMode::Partial;
    let app = Arc::new(StallApp { slow_rank: usize::MAX, own_ms: 0, recovery_ms: 0 });
    let rep = run_app(app, &opts).unwrap();
    assert_eq!(rep.dead_ranks, vec![0]);
    let uncovered = rep.uncovered_pairs.clone();
    assert!(!uncovered.is_empty(), "r = 1 plus a death must exhaust some pair");
    for &(a, b) in &uncovered {
        assert!(a <= b, "manifest pairs must be normalized, got ({a}, {b})");
    }
    let mut sorted = uncovered.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted, uncovered, "manifest must be sorted and duplicate-free");
    // Exactly-once over the covered remainder: all pairs minus manifest.
    let covered: Vec<(usize, usize)> =
        all_pairs().into_iter().filter(|p| !uncovered.contains(p)).collect();
    assert_eq!(
        collect_pairs(&rep.results),
        covered,
        "covered pairs must still arrive exactly once"
    );
    let total = (P * (P + 1) / 2) as f64;
    let want = 1.0 - uncovered.len() as f64 / total;
    assert!(
        (rep.coverage_ratio - want).abs() < 1e-9,
        "coverage ratio {} != {want}",
        rep.coverage_ratio
    );
    assert!(rep.coverage_ratio < 1.0);
}

#[test]
fn degrade_partial_pcit_network_is_covered_subset() {
    // Threshold-mode PCIT under exhaustion: the degraded network must be
    // exactly the failure-free network minus the uncovered tiles — every
    // surviving edge bitwise-present in the baseline.
    let d = dataset(90);
    let base =
        run_resilient_pcit_at(&pcit_cfg(Strategy::Cyclic, false), &d, exec(), 2, &[], KillAt::Scatter)
            .unwrap();
    let mut cfg = pcit_cfg(Strategy::Cyclic, false);
    cfg.degrade = DegradeMode::Partial;
    cfg.steal = false;
    let rep =
        run_resilient_pcit_at(&cfg, &d, exec(), 1, &[0], KillAt::Compute { tasks: 1 }).unwrap();
    assert_eq!(rep.dead_ranks, vec![0]);
    assert!(!rep.uncovered_pairs.is_empty());
    assert!(rep.coverage_ratio < 1.0);
    for e in &rep.network.edges {
        assert!(
            base.network.edges.contains(e),
            "degraded edge {e:?} absent from the failure-free network"
        );
    }
    assert!(
        rep.network.n_edges() <= base.network.n_edges(),
        "degradation cannot add edges"
    );
}
