//! Integration: the app-agnostic engine — pluggable placement strategies
//! (cyclic / grid / full), app parity against single-node paths, and
//! failure injection (the transport's killed path must surface a clean
//! leader error, never a hang).

use quorall::apps::nbody::{forces_direct, forces_quorum, run_distributed_nbody, Bodies};
use quorall::apps::similarity::{
    run_distributed_similarity, similarity_direct, similarity_quorum,
};
use quorall::apps::{DistMode, PcitApp};
use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{
    run_app, run_distributed_pcit, run_single_node, BlockData, DistributedApp, EngineOptions,
    Payload, WorkerCtx,
};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::pcit::standardize_rows;
use quorall::pool::ThreadPool;
use quorall::quorum::Strategy;
use quorall::runtime::{Executor, NativeBackend};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::sync::Arc;

fn exec() -> Executor {
    Arc::new(NativeBackend::new())
}

fn dataset(genes: usize) -> ExpressionDataset {
    ExpressionDataset::generate(SyntheticSpec {
        genes,
        samples: 24,
        modules: 6,
        noise: 0.5,
        seed: 91,
    })
}

// ---- PCIT under every placement strategy ----

#[test]
fn pcit_identical_under_all_strategies() {
    let d = dataset(96);
    let single = run_single_node(&d, 2, None);
    for strategy in Strategy::all() {
        for ranks in [4usize, 8] {
            let cfg = RunConfig {
                ranks,
                mode: PcitMode::QuorumExact,
                strategy,
                ..RunConfig::default()
            };
            let rep = run_distributed_pcit(&cfg, &d, exec()).unwrap();
            assert!(
                rep.network.same_edges(&single.network),
                "strategy {} P={ranks}: {} vs {} edges",
                strategy.name(),
                rep.network.n_edges(),
                single.network.n_edges()
            );
        }
    }
}

#[test]
fn strategy_memory_ordering_measured() {
    // The Fig. 2-R comparison as measured peaks: cyclic < grid < full at
    // P = 8 (cyclic k = 4 < grid 5 < full 8 input blocks per rank).
    let d = dataset(128);
    let mut peaks = Vec::new();
    for strategy in Strategy::all() {
        let cfg = RunConfig {
            ranks: 8,
            mode: PcitMode::QuorumExact,
            strategy,
            ..RunConfig::default()
        };
        let rep = run_distributed_pcit(&cfg, &d, exec()).unwrap();
        peaks.push((strategy.name(), rep.peak_bytes_per_rank));
    }
    let get = |name: &str| peaks.iter().find(|(n, _)| *n == name).unwrap().1;
    assert!(
        get("cyclic") < get("grid"),
        "cyclic must beat grid (dual arrays): {peaks:?}"
    );
    assert!(
        get("grid") < get("full"),
        "grid must beat full replication: {peaks:?}"
    );
}

// ---- Similarity parity (bitwise across strategies) ----

#[test]
fn similarity_parity_all_strategies() {
    let mut rng = Rng::new(3);
    let f = Matrix::from_fn(60, 16, |_, _| rng.normal_f32());
    let pool = ThreadPool::new(2);
    let e = exec();
    let direct = similarity_direct(&f);
    let pooled = similarity_quorum(&f, 8, &e, &pool).unwrap();
    for strategy in Strategy::all() {
        let opts = EngineOptions::new(8, strategy);
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        // Tiles are placement-independent dot products: bitwise equal to
        // the in-process pooled path, tight against the direct matmul.
        assert_eq!(
            sim.as_slice(),
            pooled.as_slice(),
            "strategy {} differs from pooled path",
            strategy.name()
        );
        assert!(
            direct.max_abs_diff(&sim) < 1e-5,
            "strategy {} drifts from direct: {}",
            strategy.name(),
            direct.max_abs_diff(&sim)
        );
        assert_eq!(rep.stats.len(), 8);
        assert!(rep.total_comm_bytes > 0);
        assert!(rep.peak_bytes_per_rank > 0);
    }
}

// ---- N-body parity ----

#[test]
fn nbody_parity_all_strategies() {
    let b = Bodies::random(60, 7);
    let pool = ThreadPool::new(2);
    let direct = forces_direct(&b);
    let pooled = forces_quorum(&b, 8, &pool).unwrap();
    for strategy in Strategy::all() {
        let opts = EngineOptions::new(8, strategy);
        let (f, rep) = run_distributed_nbody(&b, &opts).unwrap();
        for i in 0..b.n {
            for dim in 0..3 {
                assert!(
                    (f[i][dim] - direct[i][dim]).abs() < 1e-9 * (1.0 + direct[i][dim].abs()),
                    "strategy {} body {i} dim {dim}: {} vs {}",
                    strategy.name(),
                    f[i][dim],
                    direct[i][dim]
                );
            }
        }
        if strategy == Strategy::Cyclic {
            // Same kernel, same task sets, same rank-ascending reduce order
            // as the pooled path ⇒ bitwise identical forces.
            for i in 0..b.n {
                assert_eq!(f[i], pooled[i], "body {i} not bitwise equal");
            }
        }
        assert_eq!(rep.stats.len(), 8);
        assert!(rep.total_comm_bytes > 0);
    }
}

// ---- Pipelined transport: bitwise parity with the synchronous path ----

#[test]
fn pcit_pipelined_bitwise_identical_to_sync() {
    // The forward-before-compute ring must run the identical elimination
    // sequence: same surviving edges, same correlation values, bit for bit,
    // under every placement strategy.
    let d = dataset(96);
    for strategy in Strategy::all() {
        for ranks in [4usize, 8] {
            let mut nets = Vec::new();
            for pipeline in [false, true] {
                let cfg = RunConfig {
                    ranks,
                    mode: PcitMode::QuorumExact,
                    strategy,
                    pipeline,
                    ..RunConfig::default()
                };
                nets.push(run_distributed_pcit(&cfg, &d, exec()).unwrap().network);
            }
            assert_eq!(
                nets[0].edges,
                nets[1].edges,
                "strategy {} P={ranks}: pipelined edges differ from sync",
                strategy.name()
            );
        }
    }
}

#[test]
fn similarity_pipelined_bitwise_identical_to_sync() {
    let mut rng = Rng::new(17);
    let f = Matrix::from_fn(60, 16, |_, _| rng.normal_f32());
    let e = exec();
    for strategy in Strategy::all() {
        let mut sims = Vec::new();
        for pipeline in [false, true] {
            let mut opts = EngineOptions::new(8, strategy);
            opts.pipeline = pipeline;
            let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
            assert!(rep.recv_blocked_secs >= 0.0);
            assert!((0.0..=1.0).contains(&rep.overlap_ratio));
            sims.push(sim);
        }
        assert_eq!(
            sims[0].as_slice(),
            sims[1].as_slice(),
            "strategy {}: streamed similarity differs from sync",
            strategy.name()
        );
    }
}

#[test]
fn nbody_pipelined_bitwise_identical_to_sync() {
    let b = Bodies::random(60, 7);
    for strategy in Strategy::all() {
        let mut forces = Vec::new();
        for pipeline in [false, true] {
            let mut opts = EngineOptions::new(8, strategy);
            opts.pipeline = pipeline;
            forces.push(run_distributed_nbody(&b, &opts).unwrap().0);
        }
        for i in 0..b.n {
            assert_eq!(
                forces[0][i],
                forces[1][i],
                "strategy {} body {i}: streamed reduce differs from sync",
                strategy.name()
            );
        }
    }
}

#[test]
fn pipelined_parity_survives_credit_exhaustion() {
    // Credit 1 forces the send-ahead paths into their fallbacks (ring:
    // compute-first ordering; streaming: stash into the final Result) —
    // results must stay bitwise identical anyway.
    let mut rng = Rng::new(23);
    let f = Matrix::from_fn(50, 12, |_, _| rng.normal_f32());
    let e = exec();
    let sync = {
        let mut opts = EngineOptions::new(8, Strategy::Cyclic);
        opts.pipeline = false;
        run_distributed_similarity(&f, &e, &opts).unwrap().0
    };
    let mut opts = EngineOptions::new(8, Strategy::Cyclic);
    opts.pipeline = true;
    opts.send_ahead_credit = 1;
    // Stolen tasks report through RecoveredResult rather than streamed
    // chunks, which would make the per-rank item count timing-dependent —
    // pin stealing off so the accounting below stays exact.
    opts.steal = false;
    let (starved, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
    assert_eq!(sync.as_slice(), starved.as_slice());
    let items: u64 = rep.stats.iter().map(|s| s.n_items).sum();
    // Streamed + stashed chunks must still account every owned tile:
    // P(P+1)/2 = 36 pairs at P = 8.
    assert_eq!(items, 36);

    // Same starvation for the ring: with credit 1 every ring step falls
    // back to compute-first ordering, which is exactly the sync protocol.
    let d = dataset(64);
    let cfg = RunConfig {
        ranks: 5,
        mode: PcitMode::QuorumExact,
        pipeline: false,
        ..RunConfig::default()
    };
    let base = run_distributed_pcit(&cfg, &d, exec()).unwrap().network;
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.pipeline = true;
    opts.send_ahead_credit = 1;
    let rep = run_app(pcit_app(&d, DistMode::Exact), &opts).unwrap();
    let mut all_edges: Vec<(usize, usize, f32)> = Vec::new();
    for (_, payload) in rep.results {
        match payload {
            quorall::coordinator::Payload::Edges(e) => all_edges.extend(e),
            other => panic!("unexpected payload {}", other.kind()),
        }
    }
    let starved_net = quorall::pcit::Network::new(64, all_edges);
    assert_eq!(base.edges, starved_net.edges);
}

#[test]
fn streaming_before_a_barrier_is_folded_not_fatal() {
    // A fast rank may stream result chunks while the leader is still
    // sequencing another rank's barrier phases; the leader must fold them
    // (in compute order) instead of aborting with "unexpected message".
    struct StreamyApp;
    impl DistributedApp for StreamyApp {
        fn name(&self) -> &'static str {
            "streamy"
        }
        fn elements(&self) -> usize {
            8
        }
        fn make_block(&self, range: std::ops::Range<usize>) -> BlockData {
            BlockData::Rows(Matrix::zeros(range.len(), 4))
        }
        fn sync_phases(&self) -> Vec<u8> {
            vec![1]
        }
        fn run_worker(&self, ctx: &mut WorkerCtx) -> Option<Payload> {
            let me = ctx.my_block;
            // Stream before reporting the phase: the chunk reaches the
            // leader mid-wait_phases.
            ctx.stream_result(Payload::Edges(vec![(me, me + 10, 0.5)]));
            ctx.phase_done(1);
            if !ctx.barrier() {
                return None;
            }
            Some(Payload::Edges(vec![(me, me + 20, 0.9)]))
        }
    }
    let mut opts = EngineOptions::new(4, Strategy::Cyclic);
    opts.pipeline = true;
    let rep = run_app(Arc::new(StreamyApp), &opts).unwrap();
    assert_eq!(rep.results.len(), 4);
    for (rank, payload) in rep.results {
        match payload {
            Payload::Edges(e) => {
                assert_eq!(e, vec![(rank, rank + 10, 0.5), (rank, rank + 20, 0.9)]);
            }
            other => panic!("rank {rank}: wrong payload {}", other.kind()),
        }
    }
}

// ---- Streamed scatter: bitwise parity with the monolithic path ----

#[test]
fn streamed_scatter_bitwise_identical_to_monolithic() {
    // All three apps × every strategy × both transports at P = 8: the
    // dependency-driven eager start must never change a single bit — the
    // per-task compute sequence is identical, only the idle window before
    // it shrinks.
    let d = dataset(96);
    let mut rng = Rng::new(29);
    let f = Matrix::from_fn(60, 16, |_, _| rng.normal_f32());
    let b = Bodies::random(60, 7);
    let e = exec();
    for strategy in Strategy::all() {
        for pipeline in [false, true] {
            // PCIT quorum-exact: identical surviving edge set.
            let mut nets = Vec::new();
            for streamed in [false, true] {
                let cfg = RunConfig {
                    ranks: 8,
                    mode: PcitMode::QuorumExact,
                    strategy,
                    pipeline,
                    streamed_scatter: streamed,
                    ..RunConfig::default()
                };
                nets.push(run_distributed_pcit(&cfg, &d, exec()).unwrap().network);
            }
            assert_eq!(
                nets[0].edges,
                nets[1].edges,
                "strategy {} pipeline {pipeline}: streamed-scatter PCIT differs",
                strategy.name()
            );

            // Similarity: bitwise matrix parity + sane scatter metrics.
            let mut sims = Vec::new();
            for streamed in [false, true] {
                let mut opts = EngineOptions::new(8, strategy);
                opts.pipeline = pipeline;
                opts.streamed_scatter = streamed;
                let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
                assert!(rep.scatter_comm_bytes > 0);
                assert!(rep.scatter_blocked_secs >= 0.0);
                assert!(
                    rep.time_to_first_task_secs.is_finite()
                        && rep.time_to_first_task_secs >= 0.0
                );
                sims.push(sim);
            }
            assert_eq!(
                sims[0].as_slice(),
                sims[1].as_slice(),
                "strategy {} pipeline {pipeline}: streamed-scatter similarity differs",
                strategy.name()
            );

            // N-body: bitwise force parity (f64 reduce order preserved).
            let mut forces = Vec::new();
            for streamed in [false, true] {
                let mut opts = EngineOptions::new(8, strategy);
                opts.pipeline = pipeline;
                opts.streamed_scatter = streamed;
                forces.push(run_distributed_nbody(&b, &opts).unwrap().0);
            }
            for i in 0..b.n {
                assert_eq!(
                    forces[0][i],
                    forces[1][i],
                    "strategy {} pipeline {pipeline} body {i}: streamed-scatter forces differ",
                    strategy.name()
                );
            }
        }
    }
}

#[test]
fn streamed_scatter_full_local_pcit_awaits_the_panel() {
    // Full-PCIT quorum-local mode scans the rank's entire quorum panel per
    // task, so under the streamed scatter the first task must wait for the
    // whole placement (WorkerCtx::ensure_blocks on the panel) — and the
    // resulting network must match the monolithic run exactly (panel =
    // owner's quorum, independent of how the blocks arrived).
    let d = dataset(80);
    let mut nets = Vec::new();
    for streamed in [false, true] {
        let cfg = RunConfig {
            ranks: 8,
            mode: PcitMode::QuorumLocal,
            streamed_scatter: streamed,
            use_pcit_significance: true,
            ..RunConfig::default()
        };
        nets.push(run_distributed_pcit(&cfg, &d, exec()).unwrap().network);
    }
    assert_eq!(nets[0].edges, nets[1].edges, "quorum-local full PCIT differs across scatter modes");
}

#[test]
fn streamed_scatter_parity_survives_credit_starvation() {
    // Credit 1 throttles the leader's block stream to one in-flight
    // message per worker — the slowest possible streamed scatter must
    // still deliver everything and stay bitwise-identical.
    let mut rng = Rng::new(31);
    let f = Matrix::from_fn(50, 12, |_, _| rng.normal_f32());
    let e = exec();
    let base = {
        let mut opts = EngineOptions::new(8, Strategy::Cyclic);
        opts.streamed_scatter = false;
        run_distributed_similarity(&f, &e, &opts).unwrap().0
    };
    let mut opts = EngineOptions::new(8, Strategy::Cyclic);
    opts.streamed_scatter = true;
    opts.pipeline = true;
    opts.send_ahead_credit = 1;
    let (starved, _) = run_distributed_similarity(&f, &e, &opts).unwrap();
    assert_eq!(base.as_slice(), starved.as_slice());
}

// ---- Hybrid intra-rank parallelism: bitwise parity across thread counts ----

#[test]
fn threads_per_rank_bitwise_identical_across_apps() {
    // The per-rank tile pool computes in parallel but commits in strict
    // serial order, so every thread count must produce the exact bits of
    // the threads_per_rank = 1 run — all three apps, both transports.
    let d = dataset(96);
    let mut rng = Rng::new(41);
    let f = Matrix::from_fn(60, 16, |_, _| rng.normal_f32());
    let b = Bodies::random(60, 7);
    let e = exec();
    for strategy in [Strategy::Cyclic, Strategy::Grid] {
        for pipeline in [false, true] {
            let mut nets = Vec::new();
            let mut sims = Vec::new();
            let mut forces = Vec::new();
            for threads in [1usize, 2, 4] {
                let cfg = RunConfig {
                    ranks: 8,
                    mode: PcitMode::QuorumExact,
                    strategy,
                    pipeline,
                    threads_per_rank: threads,
                    ..RunConfig::default()
                };
                nets.push(run_distributed_pcit(&cfg, &d, exec()).unwrap().network);

                let mut opts = EngineOptions::new(8, strategy);
                opts.pipeline = pipeline;
                opts.threads_per_rank = threads;
                sims.push(run_distributed_similarity(&f, &e, &opts).unwrap().0);
                forces.push(run_distributed_nbody(&b, &opts).unwrap().0);
            }
            for (t, threads) in [2usize, 4].iter().enumerate().map(|(i, &t)| (i + 1, t)) {
                assert_eq!(
                    nets[0].edges,
                    nets[t].edges,
                    "strategy {} pipeline {pipeline}: PCIT edges differ at {threads} threads",
                    strategy.name()
                );
                assert_eq!(
                    sims[0].as_slice(),
                    sims[t].as_slice(),
                    "strategy {} pipeline {pipeline}: similarity differs at {threads} threads",
                    strategy.name()
                );
                for i in 0..b.n {
                    assert_eq!(
                        forces[0][i],
                        forces[t][i],
                        "strategy {} pipeline {pipeline} body {i}: forces differ at {threads} threads",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn threads_per_rank_bitwise_identical_local_pcit() {
    // Quorum-local mode takes the other pooled path (per-task panel
    // assembly via parallel_map + whole-panel elimination scan) — it must
    // be just as boundary-independent.
    let d = dataset(80);
    let mut nets = Vec::new();
    for threads in [1usize, 4] {
        let cfg = RunConfig {
            ranks: 8,
            mode: PcitMode::QuorumLocal,
            use_pcit_significance: true,
            threads_per_rank: threads,
            ..RunConfig::default()
        };
        nets.push(run_distributed_pcit(&cfg, &d, exec()).unwrap().network);
    }
    assert_eq!(nets[0].edges, nets[1].edges, "quorum-local PCIT differs across thread counts");
}

// ---- Failure injection: clean errors, no hangs ----

fn pcit_app(d: &ExpressionDataset, mode: DistMode) -> Arc<PcitApp> {
    Arc::new(PcitApp::new(standardize_rows(&d.expr), exec(), mode, true, 0.85))
}

#[test]
fn killed_rank_mid_exact_phase_errors_cleanly() {
    // Rank 2 crashes after receiving its data; the exact-mode barrier can
    // never complete. The leader must detect the loss, unblock every
    // worker, and surface an error — not hang.
    let d = dataset(48);
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.kill = vec![2];
    let err = run_app(pcit_app(&d, DistMode::Exact), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 2") && msg.contains("crashed"), "unexpected error: {msg}");
}

#[test]
fn killed_rank_during_gather_errors_cleanly() {
    // Local mode has no barrier; the loss shows up as a missing result.
    let d = dataset(48);
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.kill = vec![1];
    let err = run_app(pcit_app(&d, DistMode::Local), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1") && msg.contains("crashed"), "unexpected error: {msg}");
}

/// An app that opts into neither task-ledger recovery (`recoverable`)
/// nor ring re-routing (`ring_recovery`) — its results are opaque to the
/// engine, so a mid-run death cannot be masked.
struct OpaqueApp;

impl DistributedApp for OpaqueApp {
    fn name(&self) -> &'static str {
        "opaque"
    }

    fn elements(&self) -> usize {
        18
    }

    fn make_block(&self, range: std::ops::Range<usize>) -> BlockData {
        BlockData::Rows(Matrix::zeros(range.len(), 4))
    }

    fn run_worker(&self, ctx: &mut WorkerCtx) -> Option<Payload> {
        let tasks = std::mem::take(&mut ctx.tasks);
        let mut edges = Vec::new();
        for t in &tasks {
            if !ctx.begin_task(t) {
                return None;
            }
            edges.push((t.a, t.b, 1.0f32));
        }
        Some(Payload::Edges(edges))
    }
}

#[test]
fn unrecoverable_app_mid_run_death_aborts_cleanly() {
    // Exact-mode PCIT now recovers by ring re-routing, so the categorical
    // abort only remains for apps that expose neither task-granular
    // results nor a ring order. Such a death must still surface a clean
    // error — not a hang, and not a silent partial result.
    let mut opts = EngineOptions::new(9, Strategy::Cyclic);
    opts.kill = vec![4];
    opts.recover = true;
    opts.redundancy = 2;
    let err = run_app(Arc::new(OpaqueApp), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("cannot recover") && msg.contains("rank 4"),
        "unexpected error: {msg}"
    );
}
