//! Integration: the app-agnostic engine — pluggable placement strategies
//! (cyclic / grid / full), app parity against single-node paths, and
//! failure injection (the transport's killed path must surface a clean
//! leader error, never a hang).

use quorall::apps::nbody::{forces_direct, forces_quorum, run_distributed_nbody, Bodies};
use quorall::apps::similarity::{
    run_distributed_similarity, similarity_direct, similarity_quorum,
};
use quorall::apps::{DistMode, PcitApp};
use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{
    run_app, run_distributed_pcit, run_single_node, EngineOptions,
};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::pcit::standardize_rows;
use quorall::pool::ThreadPool;
use quorall::quorum::Strategy;
use quorall::runtime::{Executor, NativeBackend};
use quorall::util::prng::Rng;
use quorall::util::Matrix;
use std::sync::Arc;

fn exec() -> Executor {
    Arc::new(NativeBackend::new())
}

fn dataset(genes: usize) -> ExpressionDataset {
    ExpressionDataset::generate(SyntheticSpec {
        genes,
        samples: 24,
        modules: 6,
        noise: 0.5,
        seed: 91,
    })
}

// ---- PCIT under every placement strategy ----

#[test]
fn pcit_identical_under_all_strategies() {
    let d = dataset(96);
    let single = run_single_node(&d, 2, None);
    for strategy in Strategy::all() {
        for ranks in [4usize, 8] {
            let cfg = RunConfig {
                ranks,
                mode: PcitMode::QuorumExact,
                strategy,
                ..RunConfig::default()
            };
            let rep = run_distributed_pcit(&cfg, &d, exec()).unwrap();
            assert!(
                rep.network.same_edges(&single.network),
                "strategy {} P={ranks}: {} vs {} edges",
                strategy.name(),
                rep.network.n_edges(),
                single.network.n_edges()
            );
        }
    }
}

#[test]
fn strategy_memory_ordering_measured() {
    // The Fig. 2-R comparison as measured peaks: cyclic < grid < full at
    // P = 8 (cyclic k = 4 < grid 5 < full 8 input blocks per rank).
    let d = dataset(128);
    let mut peaks = Vec::new();
    for strategy in Strategy::all() {
        let cfg = RunConfig {
            ranks: 8,
            mode: PcitMode::QuorumExact,
            strategy,
            ..RunConfig::default()
        };
        let rep = run_distributed_pcit(&cfg, &d, exec()).unwrap();
        peaks.push((strategy.name(), rep.peak_bytes_per_rank));
    }
    let get = |name: &str| peaks.iter().find(|(n, _)| *n == name).unwrap().1;
    assert!(
        get("cyclic") < get("grid"),
        "cyclic must beat grid (dual arrays): {peaks:?}"
    );
    assert!(
        get("grid") < get("full"),
        "grid must beat full replication: {peaks:?}"
    );
}

// ---- Similarity parity (bitwise across strategies) ----

#[test]
fn similarity_parity_all_strategies() {
    let mut rng = Rng::new(3);
    let f = Matrix::from_fn(60, 16, |_, _| rng.normal_f32());
    let pool = ThreadPool::new(2);
    let e = exec();
    let direct = similarity_direct(&f);
    let pooled = similarity_quorum(&f, 8, &e, &pool).unwrap();
    for strategy in Strategy::all() {
        let opts = EngineOptions::new(8, strategy);
        let (sim, rep) = run_distributed_similarity(&f, &e, &opts).unwrap();
        // Tiles are placement-independent dot products: bitwise equal to
        // the in-process pooled path, tight against the direct matmul.
        assert_eq!(
            sim.as_slice(),
            pooled.as_slice(),
            "strategy {} differs from pooled path",
            strategy.name()
        );
        assert!(
            direct.max_abs_diff(&sim) < 1e-5,
            "strategy {} drifts from direct: {}",
            strategy.name(),
            direct.max_abs_diff(&sim)
        );
        assert_eq!(rep.stats.len(), 8);
        assert!(rep.total_comm_bytes > 0);
        assert!(rep.peak_bytes_per_rank > 0);
    }
}

// ---- N-body parity ----

#[test]
fn nbody_parity_all_strategies() {
    let b = Bodies::random(60, 7);
    let pool = ThreadPool::new(2);
    let direct = forces_direct(&b);
    let pooled = forces_quorum(&b, 8, &pool).unwrap();
    for strategy in Strategy::all() {
        let opts = EngineOptions::new(8, strategy);
        let (f, rep) = run_distributed_nbody(&b, &opts).unwrap();
        for i in 0..b.n {
            for dim in 0..3 {
                assert!(
                    (f[i][dim] - direct[i][dim]).abs() < 1e-9 * (1.0 + direct[i][dim].abs()),
                    "strategy {} body {i} dim {dim}: {} vs {}",
                    strategy.name(),
                    f[i][dim],
                    direct[i][dim]
                );
            }
        }
        if strategy == Strategy::Cyclic {
            // Same kernel, same task sets, same rank-ascending reduce order
            // as the pooled path ⇒ bitwise identical forces.
            for i in 0..b.n {
                assert_eq!(f[i], pooled[i], "body {i} not bitwise equal");
            }
        }
        assert_eq!(rep.stats.len(), 8);
        assert!(rep.total_comm_bytes > 0);
    }
}

// ---- Failure injection: clean errors, no hangs ----

fn pcit_app(d: &ExpressionDataset, mode: DistMode) -> Arc<PcitApp> {
    Arc::new(PcitApp::new(standardize_rows(&d.expr), exec(), mode, true, 0.85))
}

#[test]
fn killed_rank_mid_exact_phase_errors_cleanly() {
    // Rank 2 crashes after receiving its data; the exact-mode barrier can
    // never complete. The leader must detect the loss, unblock every
    // worker, and surface an error — not hang.
    let d = dataset(48);
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.kill = vec![2];
    let err = run_app(pcit_app(&d, DistMode::Exact), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 2") && msg.contains("crashed"), "unexpected error: {msg}");
}

#[test]
fn killed_rank_during_gather_errors_cleanly() {
    // Local mode has no barrier; the loss shows up as a missing result.
    let d = dataset(48);
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.kill = vec![1];
    let err = run_app(pcit_app(&d, DistMode::Local), &opts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank 1") && msg.contains("crashed"), "unexpected error: {msg}");
}

#[test]
fn resilient_runs_reject_barrier_apps() {
    let d = dataset(48);
    let mut opts = EngineOptions::new(5, Strategy::Cyclic);
    opts.kill = vec![1];
    opts.tolerate_kills = true;
    let err = run_app(pcit_app(&d, DistMode::Exact), &opts).unwrap_err();
    assert!(format!("{err:#}").contains("barrier-free"));
}
