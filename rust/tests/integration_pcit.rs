//! Integration: distributed PCIT across modes, sizes, rank counts —
//! the headline correctness contract (quorum-exact == single-node).

use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{run_distributed_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::prop::forall;
use quorall::runtime::NativeBackend;
use std::sync::Arc;

fn dataset(genes: usize, samples: usize, seed: u64) -> ExpressionDataset {
    ExpressionDataset::generate(SyntheticSpec {
        genes,
        samples,
        modules: (genes / 24).max(2),
        noise: 0.55,
        seed,
    })
}

fn cfg(ranks: usize, mode: PcitMode) -> RunConfig {
    RunConfig { ranks, mode, ..RunConfig::default() }
}

#[test]
fn exact_matches_single_across_rank_counts() {
    let d = dataset(130, 30, 17);
    let single = run_single_node(&d, 4, None);
    for ranks in [4usize, 5, 8, 11, 13, 16] {
        let rep = run_distributed_pcit(&cfg(ranks, PcitMode::QuorumExact), &d, Arc::new(NativeBackend::new()))
            .unwrap();
        assert!(
            rep.network.same_edges(&single.network),
            "P={ranks}: {} vs {} edges",
            rep.network.n_edges(),
            single.network.n_edges()
        );
    }
}

#[test]
fn exact_matches_when_blocks_are_uneven() {
    // N not divisible by P, including empty trailing blocks (N < P·block).
    for (genes, ranks) in [(97usize, 8usize), (50, 7), (33, 16), (20, 16)] {
        let d = dataset(genes, 24, genes as u64);
        let single = run_single_node(&d, 2, None);
        let rep = run_distributed_pcit(&cfg(ranks, PcitMode::QuorumExact), &d, Arc::new(NativeBackend::new()))
            .unwrap();
        assert!(
            rep.network.same_edges(&single.network),
            "N={genes} P={ranks}: {} vs {} edges",
            rep.network.n_edges(),
            single.network.n_edges()
        );
    }
}

#[test]
fn prop_distributed_equals_single() {
    forall("distributed == single", 8, |g| {
        let genes = g.usize_in(24, 90);
        let samples = g.usize_in(8, 40);
        let ranks = *g.pick(&[4usize, 6, 9, 12]);
        let d = dataset(genes, samples, g.u64());
        let single = run_single_node(&d, 2, None);
        let rep = run_distributed_pcit(&cfg(ranks, PcitMode::QuorumExact), &d, Arc::new(NativeBackend::new()))
            .unwrap();
        assert!(rep.network.same_edges(&single.network), "N={genes} M={samples} P={ranks}");
    });
}

#[test]
fn local_mode_is_superset_and_close() {
    let d = dataset(120, 36, 3);
    let single = run_single_node(&d, 4, None);
    for ranks in [6usize, 9, 16] {
        let rep = run_distributed_pcit(&cfg(ranks, PcitMode::QuorumLocal), &d, Arc::new(NativeBackend::new()))
            .unwrap();
        // Fewer mediators → strictly fewer eliminations → edge superset.
        assert!(rep.network.n_edges() >= single.network.n_edges(), "P={ranks}");
        let j = rep.network.jaccard(&single.network);
        assert!(j > 0.4, "P={ranks} jaccard {j}");
    }
}

#[test]
fn quorum_memory_advantage_holds() {
    // Paper Fig. 2-R: memory/rank shrinks with P; quorum input share is
    // k/P·N rather than N.
    let d = dataset(160, 32, 5);
    let single = run_single_node(&d, 2, None);
    let r16 = run_distributed_pcit(&cfg(16, PcitMode::QuorumExact), &d, Arc::new(NativeBackend::new()))
        .unwrap();
    assert!(
        (r16.peak_bytes_per_rank as f64) < 0.5 * single.logical_bytes as f64,
        "16 ranks should use <50% of single-node memory: {} vs {}",
        r16.peak_bytes_per_rank,
        single.logical_bytes
    );
}

#[test]
fn comm_accounting_is_consistent() {
    let d = dataset(96, 24, 9);
    let rep = run_distributed_pcit(&cfg(8, PcitMode::QuorumExact), &d, Arc::new(NativeBackend::new()))
        .unwrap();
    let sent: u64 = rep.stats.iter().map(|s| s.sent_bytes).sum();
    let recv: u64 = rep.stats.iter().map(|s| s.recv_bytes).sum();
    // Workers' sends all arrive somewhere (leader included); totals are
    // dominated by worker↔worker traffic so sent ≈ recv at worker level
    // modulo leader-originated scatter (recv > 0 everywhere).
    assert!(sent > 0 && recv > 0);
    assert!(rep.total_comm_bytes >= recv, "global counter covers worker recv");
    for s in &rep.stats {
        assert!(s.recv_bytes > 0, "rank {} received nothing", s.rank);
        assert!(s.corr_tiles > 0 || s.elim_tiles > 0, "rank {} did no work", s.rank);
    }
}

#[test]
fn threshold_mode_distributed_matches() {
    let d = dataset(110, 28, 21);
    let single = run_single_node(&d, 2, Some(0.55));
    let mut c = cfg(9, PcitMode::QuorumExact);
    c.use_pcit_significance = false;
    c.threshold = 0.55;
    let rep = run_distributed_pcit(&c, &d, Arc::new(NativeBackend::new())).unwrap();
    assert!(rep.network.same_edges(&single.network));
}
