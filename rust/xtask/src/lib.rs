//! `quorall-analyze` — static protocol-conformance checks over the
//! coordinator sources.
//!
//! The coordinator is an ~8k-line hand-rolled distributed protocol: 18
//! `Message` wire tags, a leader ledger that reassigns/steals/revokes/
//! rejoins, and TCP reader/heartbeat threads. Nothing in the type system
//! ties a new enum variant to its codec arms, its dispatch arms, or its
//! report fields — one missed decode arm silently breaks the bitwise
//! recovery guarantees the r-fold replication depends on. This pass closes
//! that gap structurally: every variant is born checked.
//!
//! Five checks (see `analyze_tree`):
//!
//! 1. **wire** — every `Message`/`Payload` variant has exactly one encode
//!    arm and one decode arm in `wire.rs`, with a unique tag, agreeing
//!    across directions, and the round-trip property test constructs it.
//! 2. **dispatch** — every `Message` variant is matched (or explicitly
//!    pragma'd `// analyze: ignore(<Variant>)` with a reason) at each
//!    dispatch site: the leader `Gather::dispatch`/`pump`, the worker
//!    phase-0/serve loop, and the worker task-boundary polls in `app.rs`.
//!    No silently-dropped protocol traffic.
//! 3. **reports** — every `RankStats` field crosses the wire
//!    (`put_stats`/`take_stats`) and every `RankStats`/`EngineReport`/
//!    `DistributedReport` field is emitted by the JSONL serializers in
//!    `driver.rs`, which the CLI actually wires up (`--jsonl`).
//! 4. **parity** — every `[run]` config key has a matching kebab-case
//!    `pcit` CLI flag and vice versa, and every `QUORALL_*` env read maps
//!    to a `[run]` key. Exemptions carry `// analyze: ignore(run.<key>)`,
//!    `// analyze: ignore(flag <name>)` or
//!    `// analyze: ignore(env QUORALL_<NAME>)` pragmas.
//! 5. **hot-path** — no `Mutex`/`RwLock`/`.lock(`/`unsafe` inside the
//!    tagged hot paths (the `transport.rs` receive path, the `matmul_nt`
//!    kernel) unless the line (or the line above) carries
//!    `// analyze: allow(lock)` / `// analyze: allow(unsafe)`.
//!
//! The build must work fully offline, so this is a hand-rolled scanner
//! (comments, strings, char literals and raw strings are masked out before
//! structural matching) rather than a `syn` AST pass — `syn` would pull in
//! proc-macro2/quote/unicode-ident, none of which are vendored.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::Path;

/// One conformance violation, anchored to a source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Repo-relative file the violation is in (as loaded).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Which check fired: `wire`, `dispatch`, `reports`, `parity`,
    /// `hot-path`, or `analyzer` (the pass could not parse what it needs —
    /// also a failure, never silently skipped).
    pub check: &'static str,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.check, self.msg)
    }
}

/// Render a finding list the way the CLI and the tier-1 test print it.
pub fn render(findings: &[Finding]) -> String {
    findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
}

/// One loaded source file: the raw text plus a *masked* copy where
/// comments, string/char literal contents and raw strings are blanked
/// (newlines preserved), so structural scans never match words inside doc
/// comments or format strings. Pragmas are comments, so they are read from
/// `raw`; code shape is read from `masked`. Both views have identical line
/// structure.
pub struct Src {
    pub name: String,
    pub raw: String,
    pub masked: String,
}

impl Src {
    pub fn new(name: impl Into<String>, raw: impl Into<String>) -> Src {
        let raw = raw.into();
        let masked = mask_source(&raw);
        Src { name: name.into(), raw, masked }
    }
}

/// Blank out comments and literal contents, preserving line structure and
/// character count. `//` and `/* */` (nested) comments become spaces;
/// `"…"` strings keep their delimiting quotes but blank the contents
/// (escapes consumed); raw strings `r"…"`/`r#"…"#`/`br#"…"#` are fully
/// blanked; char literals keep their quotes; lifetimes (`'a`) pass
/// through. This is not a full lexer — it is exactly enough to make
/// substring scans over code safe.
pub fn mask_source(raw: &str) -> String {
    let b: Vec<char> = raw.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(raw.len());
    let keep = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nested).
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < n {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(keep(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: r"…", r#"…"#, br"…", br#"…"#.
        if c == 'r' || c == 'b' {
            let prev_ident = i > 0 && is_ident(b[i - 1]);
            let mut j = i;
            if b[j] == 'b' && b.get(j + 1) == Some(&'r') {
                j += 1;
            }
            if !prev_ident && b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while b.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if b.get(k) == Some(&'"') {
                    // Blank the whole opener.
                    for _ in i..=k {
                        out.push(' ');
                    }
                    i = k + 1;
                    // Scan for `"` + hashes closer.
                    'raw: while i < n {
                        if b[i] == '"' {
                            let mut h = 0usize;
                            while h < hashes && b.get(i + 1 + h) == Some(&'#') {
                                h += 1;
                            }
                            if h == hashes {
                                for _ in 0..=hashes {
                                    out.push(' ');
                                }
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        out.push(keep(b[i]));
                        i += 1;
                    }
                    continue;
                }
            }
            if c == 'b' && b.get(i + 1) == Some(&'"') {
                // Byte string: blank the prefix, let the `"` branch below
                // handle the body on the next iteration.
                out.push(' ');
                i += 1;
                continue;
            }
            out.push(c);
            i += 1;
            continue;
        }
        // Plain string.
        if c == '"' {
            out.push('"');
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    out.push(' ');
                    if i + 1 < n {
                        out.push(keep(b[i + 1]));
                    }
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                }
                out.push(keep(b[i]));
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            let escaped = b.get(i + 1) == Some(&'\\');
            let closed = b.get(i + 2) == Some(&'\'');
            if escaped || closed {
                out.push('\'');
                i += 1;
                while i < n && b[i] != '\'' {
                    if b[i] == '\\' {
                        out.push(' ');
                        if i + 1 < n {
                            out.push(keep(b[i + 1]));
                        }
                        i += 2;
                        continue;
                    }
                    out.push(keep(b[i]));
                    i += 1;
                }
                if i < n {
                    out.push('\'');
                    i += 1;
                }
            } else {
                out.push('\''); // lifetime tick
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// 1-based line of a byte offset (both views preserve newlines).
fn line_at(text: &str, off: usize) -> usize {
    text.as_bytes()[..off.min(text.len())].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Byte offset of the `}` matching the `{` at `open` (masked text).
fn match_brace(masked: &str, open: usize) -> Option<usize> {
    let bytes = masked.as_bytes();
    debug_assert_eq!(bytes[open], b'{');
    let mut depth = 0usize;
    for (i, &c) in bytes.iter().enumerate().skip(open) {
        match c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Find `pat` in `text` at a position where the preceding char is not an
/// identifier char (so `fn pump(` never matches inside `self.pump(` and
/// `Message::Result` never matches inside `XMessage::`). The left-boundary
/// check only applies when the pattern starts with an identifier char —
/// `.rank` legitimately follows `s`.
fn find_token(text: &str, pat: &str, from: usize) -> Option<usize> {
    let head_ident = pat.chars().next().map(is_ident).unwrap_or(false);
    let mut start = from;
    while let Some(rel) = text[start..].find(pat) {
        let off = start + rel;
        let ok =
            !head_ident || off == 0 || !is_ident(text[..off].chars().next_back().unwrap());
        if ok {
            return Some(off);
        }
        start = off + pat.len();
    }
    None
}

/// Whether `text` contains `pat` as a token: preceding and following chars
/// are not identifier chars (the pattern itself may end in punctuation, in
/// which case only the left boundary matters).
fn contains_token(text: &str, pat: &str) -> bool {
    let mut from = 0;
    while let Some(off) = find_token(text, pat, from) {
        let after = text[off + pat.len()..].chars().next();
        let tail_ident = pat.chars().next_back().map(is_ident).unwrap_or(false);
        if !tail_ident || !after.map(is_ident).unwrap_or(false) {
            return true;
        }
        from = off + pat.len();
    }
    false
}

/// The extracted body of one `fn`: its declaration line plus masked and
/// raw views of the decl-through-closing-brace line range.
pub struct FnBody {
    pub decl_line: usize,
    pub masked: String,
    pub raw: String,
}

/// Extract `fn name(…) { … }` from a source file (first match). Returns
/// `None` when the fn is missing — callers report that as a finding, never
/// skip silently.
pub fn fn_body(src: &Src, name: &str) -> Option<FnBody> {
    let pat = format!("fn {name}(");
    let decl = find_token(&src.masked, &pat, 0)?;
    let open = decl + src.masked[decl..].find('{')?;
    let close = match_brace(&src.masked, open)?;
    let decl_line = line_at(&src.masked, decl);
    let end_line = line_at(&src.masked, close);
    let slice = |text: &str| {
        text.lines()
            .skip(decl_line - 1)
            .take(end_line - decl_line + 1)
            .collect::<Vec<_>>()
            .join("\n")
    };
    Some(FnBody { decl_line, masked: slice(&src.masked), raw: slice(&src.raw) })
}

/// Split `body` (the text between an item's braces) at top-level commas —
/// commas nested in `{}`, `()` or `[]` (variant payloads, tuple fields,
/// generic arguments inside them) do not split.
fn split_top_level(body: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in body.char_indices() {
        match c {
            '{' | '(' | '[' => depth += 1,
            '}' | ')' | ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push((start, body[start..i].to_string()));
                start = i + c.len_utf8();
            }
            _ => {}
        }
    }
    if start < body.len() {
        out.push((start, body[start..].to_string()));
    }
    out
}

/// First identifier in `seg`, skipping `#[…]` attributes and the `pub`
/// keyword. Returns the ident and its offset within `seg`.
fn first_ident(seg: &str) -> Option<(String, usize)> {
    let bytes: Vec<char> = seg.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == '#' {
            // Skip the attribute's bracket group.
            let mut depth = 0usize;
            while i < bytes.len() {
                match bytes[i] {
                    '[' => depth += 1,
                    ']' => {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            continue;
        }
        if is_ident(bytes[i]) && !bytes[i].is_ascii_digit() {
            let start = i;
            while i < bytes.len() && is_ident(bytes[i]) {
                i += 1;
            }
            let word: String = bytes[start..i].iter().collect();
            if word == "pub" || word == "crate" || word == "super" {
                // `pub` / `pub(crate)` visibility — keep scanning.
                continue;
            }
            let byte_off = seg.char_indices().nth(start).map(|(o, _)| o).unwrap_or(0);
            return Some((word, byte_off));
        }
        i += 1;
    }
    None
}

/// Variant names of `enum name { … }` with their 1-based lines.
pub fn enum_variants(src: &Src, name: &str) -> Vec<(String, usize)> {
    item_idents(src, &format!("enum {name}"), first_ident)
}

/// Field names of `struct name { … }` with their 1-based lines.
pub fn struct_fields(src: &Src, name: &str) -> Vec<(String, usize)> {
    item_idents(src, &format!("struct {name}"), |seg| {
        let colon = seg.find(':')?;
        first_ident(&seg[..colon])
    })
}

fn item_idents(
    src: &Src,
    header: &str,
    pick: impl Fn(&str) -> Option<(String, usize)>,
) -> Vec<(String, usize)> {
    let Some(decl) = find_token(&src.masked, header, 0) else {
        return Vec::new();
    };
    // Guard against matching `enum Name` inside `enum NameLonger`.
    let after = src.masked[decl + header.len()..].chars().next();
    if after.map(is_ident).unwrap_or(false) {
        return Vec::new();
    }
    let Some(open_rel) = src.masked[decl..].find('{') else {
        return Vec::new();
    };
    let open = decl + open_rel;
    let Some(close) = match_brace(&src.masked, open) else {
        return Vec::new();
    };
    let body = &src.masked[open + 1..close];
    split_top_level(body)
        .into_iter()
        .filter_map(|(seg_off, seg)| {
            let (ident, ident_off) = pick(&seg)?;
            let line = line_at(&src.masked, open + 1 + seg_off + ident_off);
            Some((ident, line))
        })
        .collect()
}

/// All `<prefix><Ident>` occurrences in `text` (e.g. prefix `Message::`),
/// with the byte offset of each match.
fn idents_after(text: &str, prefix: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(off) = find_token(text, prefix, from) {
        let rest = &text[off + prefix.len()..];
        let ident: String = rest.chars().take_while(|&c| is_ident(c)).collect();
        if !ident.is_empty() {
            out.push((ident, off));
        }
        from = off + prefix.len();
    }
    out
}

/// All `// analyze: ignore(<item>)` pragma payloads in a file (raw view —
/// pragmas are comments). Items are free-form: a variant name, `run.<key>`,
/// `flag <name>`, `env QUORALL_<NAME>`.
pub fn ignore_pragmas(src: &Src) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in src.raw.lines() {
        let mut rest = line;
        while let Some(i) = rest.find("analyze: ignore(") {
            let tail = &rest[i + "analyze: ignore(".len()..];
            if let Some(end) = tail.find(')') {
                out.insert(tail[..end].trim().to_string());
                rest = &tail[end..];
            } else {
                break;
            }
        }
    }
    out
}

// ---- check 1: wire codec conformance -----------------------------------

/// Sequential events inside a codec fn body: a variant mention or a
/// `put_u8(…, <literal>)` tag write.
enum CodecEvent {
    Variant(String, usize),
    Tag(u32, usize),
}

fn codec_events(body: &FnBody, prefix: &str) -> Vec<CodecEvent> {
    let mut ev: Vec<(usize, CodecEvent)> = Vec::new();
    for (ident, off) in idents_after(&body.masked, prefix) {
        let line = body.decl_line + line_at(&body.masked, off) - 1;
        ev.push((off, CodecEvent::Variant(ident, line)));
    }
    let mut from = 0;
    while let Some(off) = find_token(&body.masked, "put_u8(", from) {
        from = off + 1;
        let rest = &body.masked[off..];
        let Some(comma) = rest.find(',') else { continue };
        let Some(close) = rest.find(')') else { continue };
        if close < comma {
            continue;
        }
        let lit = rest[comma + 1..close].trim();
        if let Ok(v) = lit.parse::<u32>() {
            let line = body.decl_line + line_at(&body.masked, off) - 1;
            ev.push((off, CodecEvent::Tag(v, line)));
        }
    }
    ev.sort_by_key(|(off, _)| *off);
    ev.into_iter().map(|(_, e)| e).collect()
}

/// Encode map: variant → (tag, line), walking `Message::X … put_u8(_, N)`
/// pairs in arm order. Extra `put_u8` writes inside an arm body are ignored
/// (only the first literal after each variant mention binds as the tag).
fn encode_map(body: &FnBody, prefix: &str) -> (BTreeMap<String, (u32, usize)>, Vec<Finding>) {
    let mut map = BTreeMap::new();
    let mut findings = Vec::new();
    let mut pending: Option<(String, usize)> = None;
    for e in codec_events(body, prefix) {
        match e {
            CodecEvent::Variant(v, line) => {
                if let Some((prev, prev_line)) = pending.take() {
                    findings.push(Finding {
                        file: String::new(),
                        line: prev_line,
                        check: "wire",
                        msg: format!("{prefix}{prev} encode arm writes no wire tag (no `put_u8` literal before the next arm)"),
                    });
                }
                pending = Some((v, line));
            }
            CodecEvent::Tag(t, line) => {
                if let Some((v, _)) = pending.take() {
                    if map.insert(v.clone(), (t, line)).is_some() {
                        findings.push(Finding {
                            file: String::new(),
                            line,
                            check: "wire",
                            msg: format!("{prefix}{v} has more than one encode arm"),
                        });
                    }
                }
            }
        }
    }
    if let Some((prev, prev_line)) = pending {
        findings.push(Finding {
            file: String::new(),
            line: prev_line,
            check: "wire",
            msg: format!("{prefix}{prev} encode arm writes no wire tag"),
        });
    }
    (map, findings)
}

/// Decode map: variant → (tag, line), reading `N => … Prefix::X` arms.
fn decode_map(body: &FnBody, prefix: &str) -> (BTreeMap<String, Vec<(u32, usize)>>, Vec<Finding>) {
    let mut map: BTreeMap<String, Vec<(u32, usize)>> = BTreeMap::new();
    let findings = Vec::new();
    // Tag events: lines whose trimmed masked text starts with a decimal
    // literal followed by `=>`.
    let mut ev: Vec<(usize, Option<u32>, usize)> = Vec::new(); // (offset, tag, line)
    let mut off = 0usize;
    for (idx, line) in body.masked.lines().enumerate() {
        let t = line.trim_start();
        let digits: String = t.chars().take_while(|c| c.is_ascii_digit()).collect();
        if !digits.is_empty() && t[digits.len()..].trim_start().starts_with("=>") {
            ev.push((off + (line.len() - t.len()), digits.parse().ok(), body.decl_line + idx));
        }
        off += line.len() + 1;
    }
    let mut variants: Vec<(String, usize)> = idents_after(&body.masked, prefix);
    variants.sort_by_key(|(_, o)| *o);
    let mut vi = 0usize;
    for (w, &(start, tag, line)) in ev.iter().enumerate() {
        let end = ev.get(w + 1).map(|&(o, _, _)| o).unwrap_or(body.masked.len());
        let Some(tag) = tag else { continue };
        // First variant mention inside this arm's span binds.
        while vi < variants.len() && variants[vi].1 < start {
            vi += 1;
        }
        if vi < variants.len() && variants[vi].1 < end {
            map.entry(variants[vi].0.clone()).or_default().push((tag, line));
        }
    }
    (map, findings)
}

/// Check 1: wire codec conformance for one enum.
fn check_codec(
    messages: &Src,
    wire: &Src,
    enum_name: &str,
    enc_fn: &str,
    dec_fn: &str,
) -> Vec<Finding> {
    let prefix = format!("{enum_name}::");
    let mut out = Vec::new();
    let variants = enum_variants(messages, enum_name);
    if variants.is_empty() {
        out.push(Finding {
            file: messages.name.clone(),
            line: 1,
            check: "analyzer",
            msg: format!("could not find `enum {enum_name}` in {}", messages.name),
        });
        return out;
    }
    let Some(enc) = fn_body(wire, enc_fn) else {
        out.push(Finding {
            file: wire.name.clone(),
            line: 1,
            check: "analyzer",
            msg: format!("could not find `fn {enc_fn}` in {}", wire.name),
        });
        return out;
    };
    let Some(dec) = fn_body(wire, dec_fn) else {
        out.push(Finding {
            file: wire.name.clone(),
            line: 1,
            check: "analyzer",
            msg: format!("could not find `fn {dec_fn}` in {}", wire.name),
        });
        return out;
    };
    let (enc_map, mut enc_findings) = encode_map(&enc, &prefix);
    for f in &mut enc_findings {
        f.file = wire.name.clone();
    }
    out.append(&mut enc_findings);
    let (dec_map, mut dec_findings) = decode_map(&dec, &prefix);
    for f in &mut dec_findings {
        f.file = wire.name.clone();
    }
    out.append(&mut dec_findings);

    // Unique encode tags.
    let mut by_tag: BTreeMap<u32, Vec<(&String, usize)>> = BTreeMap::new();
    for (v, &(t, line)) in &enc_map {
        by_tag.entry(t).or_default().push((v, line));
    }
    for (t, vs) in &by_tag {
        if vs.len() > 1 {
            let names: Vec<&str> = vs.iter().map(|(v, _)| v.as_str()).collect();
            out.push(Finding {
                file: wire.name.clone(),
                line: vs.last().unwrap().1,
                check: "wire",
                msg: format!(
                    "duplicate wire tag {t} in {enc_fn}: {} all encode as {t}",
                    names.join(", ")
                ),
            });
        }
    }

    for (v, vline) in &variants {
        match enc_map.get(v) {
            None => out.push(Finding {
                file: messages.name.clone(),
                line: *vline,
                check: "wire",
                msg: format!("{prefix}{v} has no encode arm in {enc_fn} ({})", wire.name),
            }),
            Some((etag, _)) => match dec_map.get(v) {
                None => out.push(Finding {
                    file: messages.name.clone(),
                    line: *vline,
                    check: "wire",
                    msg: format!("{prefix}{v} has no decode arm in {dec_fn} ({})", wire.name),
                }),
                Some(tags) => {
                    if tags.len() > 1 {
                        out.push(Finding {
                            file: wire.name.clone(),
                            line: tags[1].1,
                            check: "wire",
                            msg: format!("{prefix}{v} has more than one decode arm in {dec_fn}"),
                        });
                    }
                    if tags[0].0 != *etag {
                        out.push(Finding {
                            file: wire.name.clone(),
                            line: tags[0].1,
                            check: "wire",
                            msg: format!(
                                "{prefix}{v} encodes as tag {etag} but decodes under tag {}",
                                tags[0].0
                            ),
                        });
                    }
                }
            },
        }
    }
    out
}

/// Check 1 (both enums) plus round-trip test coverage.
pub fn check_wire(messages: &Src, wire: &Src) -> Vec<Finding> {
    let mut out = check_codec(messages, wire, "Message", "encode_message", "take_message");
    out.extend(check_codec(messages, wire, "Payload", "put_payload", "take_payload"));

    let rt_fn = "every_message_variant_round_trips_framed";
    match fn_body(wire, rt_fn) {
        None => out.push(Finding {
            file: wire.name.clone(),
            line: 1,
            check: "analyzer",
            msg: format!("could not find the round-trip property test `fn {rt_fn}`"),
        }),
        Some(rt) => {
            for (enum_name, prefix) in [("Message", "Message::"), ("Payload", "Payload::")] {
                let covered: BTreeSet<String> =
                    idents_after(&rt.masked, prefix).into_iter().map(|(v, _)| v).collect();
                for (v, vline) in enum_variants(messages, enum_name) {
                    if !covered.contains(&v) {
                        out.push(Finding {
                            file: messages.name.clone(),
                            line: vline,
                            check: "wire",
                            msg: format!("{prefix}{v} is not constructed by the round-trip property test {rt_fn}"),
                        });
                    }
                }
            }
        }
    }
    out
}

// ---- check 2: dispatch coverage ----------------------------------------

/// One dispatch site: a set of fns in one file whose match arms, taken
/// together, must cover every `Message` variant (or pragma it away).
pub struct DispatchSite<'a> {
    pub name: &'a str,
    pub file: &'a Src,
    pub fns: &'a [&'a str],
}

pub fn check_dispatch(messages: &Src, sites: &[DispatchSite<'_>]) -> Vec<Finding> {
    let variants = enum_variants(messages, "Message");
    let mut out = Vec::new();
    if variants.is_empty() {
        out.push(Finding {
            file: messages.name.clone(),
            line: 1,
            check: "analyzer",
            msg: format!("could not find `enum Message` in {}", messages.name),
        });
        return out;
    }
    for site in sites {
        let mut handled: BTreeSet<String> = BTreeSet::new();
        let mut anchor = 1usize;
        for (i, f) in site.fns.iter().enumerate() {
            match fn_body(site.file, f) {
                Some(body) => {
                    if i == 0 {
                        anchor = body.decl_line;
                    }
                    handled
                        .extend(idents_after(&body.masked, "Message::").into_iter().map(|(v, _)| v));
                }
                None => out.push(Finding {
                    file: site.file.name.clone(),
                    line: 1,
                    check: "analyzer",
                    msg: format!("dispatch site `{}`: could not find `fn {f}`", site.name),
                }),
            }
        }
        let ignored = ignore_pragmas(site.file);
        for (v, _) in &variants {
            if !handled.contains(v) && !ignored.contains(v) {
                out.push(Finding {
                    file: site.file.name.clone(),
                    line: anchor,
                    check: "dispatch",
                    msg: format!(
                        "Message::{v} is neither matched nor `// analyze: ignore({v})`-pragma'd at dispatch site `{}` — arriving one would hit the catch-all",
                        site.name
                    ),
                });
            }
        }
    }
    out
}

// ---- check 3: report-field conformance ---------------------------------

pub fn check_reports(driver: &Src, wire: &Src, main: &Src) -> Vec<Finding> {
    let mut out = Vec::new();

    // RankStats crosses the wire: put_stats writes `s.<field>` and
    // take_stats fills `<field>:`.
    let rank_fields = struct_fields(driver, "RankStats");
    if rank_fields.is_empty() {
        out.push(Finding {
            file: driver.name.clone(),
            line: 1,
            check: "analyzer",
            msg: "could not find `struct RankStats`".into(),
        });
    }
    for (dir, fn_name, pat) in
        [("written by", "put_stats", "."), ("read back by", "take_stats", "")]
    {
        match fn_body(wire, fn_name) {
            None => out.push(Finding {
                file: wire.name.clone(),
                line: 1,
                check: "analyzer",
                msg: format!("could not find `fn {fn_name}` in {}", wire.name),
            }),
            Some(body) => {
                for (f, fline) in &rank_fields {
                    let needle = if pat == "." { format!(".{f}") } else { format!("{f}:") };
                    if !contains_token(&body.masked, &needle) {
                        out.push(Finding {
                            file: driver.name.clone(),
                            line: *fline,
                            check: "reports",
                            msg: format!("RankStats::{f} is not {dir} {fn_name} in {} — the field would silently not survive the wire", wire.name),
                        });
                    }
                }
            }
        }
    }

    // Every report struct field is emitted by its JSONL serializer.
    for (struct_name, json_fn) in [
        ("RankStats", "rank_stats_json"),
        ("EngineReport", "engine_report_json"),
        ("DistributedReport", "distributed_report_json"),
    ] {
        let fields = struct_fields(driver, struct_name);
        match fn_body(driver, json_fn) {
            None => out.push(Finding {
                file: driver.name.clone(),
                line: 1,
                check: "reports",
                msg: format!("no `fn {json_fn}` in {} — {struct_name} has no JSONL serializer", driver.name),
            }),
            Some(body) => {
                for (f, fline) in &fields {
                    if !body.raw.contains(&format!("\"{f}\"")) {
                        out.push(Finding {
                            file: driver.name.clone(),
                            line: *fline,
                            check: "reports",
                            msg: format!("{struct_name}::{f} is not emitted by {json_fn} — JSONL reports would drift from the struct"),
                        });
                    }
                }
            }
        }
    }

    // The CLI actually emits the JSONL (the serializers are not dead code).
    for json_fn in ["engine_report_json", "distributed_report_json"] {
        if !contains_token(&main.masked, json_fn) {
            out.push(Finding {
                file: main.name.clone(),
                line: 1,
                check: "reports",
                msg: format!("{json_fn} is never called from {} — JSONL emission is not wired into the CLI", main.name),
            });
        }
    }
    out
}

// ---- check 4: flag ↔ config ↔ env parity -------------------------------

/// Collect `("section", "key")` string pairs from the raw text, e.g. every
/// `doc.get_str("run", "ranks")`.
fn config_keys(schema: &Src, section: &str) -> BTreeMap<String, usize> {
    let pat = format!("\"{section}\", \"");
    let mut out = BTreeMap::new();
    let mut from = 0;
    while let Some(rel) = schema.raw[from..].find(&pat) {
        let off = from + rel + pat.len();
        let key: String = schema.raw[off..].chars().take_while(|&c| is_ident(c)).collect();
        if !key.is_empty() {
            out.entry(key).or_insert_with(|| line_at(&schema.raw, off));
        }
        from = off;
    }
    out
}

/// Flags declared in one `Command::new("<cmd>" …)` builder region of
/// main.rs (raw view — names are string literals).
fn command_flags(main: &Src, cmd: &str, next_cmds: &[&str]) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    let Some(start) = main.raw.find(&format!("Command::new(\"{cmd}\"")) else {
        return out;
    };
    let end = next_cmds
        .iter()
        .filter_map(|c| main.raw[start..].find(&format!("Command::new(\"{c}\"")))
        .min()
        .map(|rel| start + rel)
        .unwrap_or(main.raw.len());
    let region = &main.raw[start..end];
    for opener in ["ArgSpec::opt(", "ArgSpec::req(", "ArgSpec::flag("] {
        let mut from = 0;
        while let Some(rel) = region[from..].find(opener) {
            let off = from + rel + opener.len();
            // The first string literal after the opener is the flag name
            // (it may sit on the next line for wrapped builder calls).
            if let Some(q) = region[off..].find('"') {
                let name_off = off + q + 1;
                let name: String = region[name_off..]
                    .chars()
                    .take_while(|&c| is_ident(c) || c == '-')
                    .collect();
                if !name.is_empty() {
                    out.entry(name).or_insert_with(|| line_at(&main.raw, start + name_off));
                }
            }
            from = off;
        }
    }
    out
}

/// Check 4: `pcit` CLI flag ↔ `[run]` config key ↔ `QUORALL_*` env parity.
/// `env_files` is every source allowed to read `QUORALL_*` variables.
pub fn check_parity(main: &Src, schema: &Src, env_files: &[&Src]) -> Vec<Finding> {
    let mut out = Vec::new();
    let run_keys = config_keys(schema, "run");
    let dataset_keys = config_keys(schema, "dataset");
    let flags = command_flags(main, "pcit", &["similarity", "nbody"]);
    if run_keys.is_empty() {
        out.push(Finding {
            file: schema.name.clone(),
            line: 1,
            check: "analyzer",
            msg: "found no `[run]` config key reads (get_*(\"run\", …)) in the schema".into(),
        });
    }
    if flags.is_empty() {
        out.push(Finding {
            file: main.name.clone(),
            line: 1,
            check: "analyzer",
            msg: "found no `pcit` ArgSpec flag declarations in main.rs".into(),
        });
    }
    let ignored_schema = ignore_pragmas(schema);
    let ignored_main = ignore_pragmas(main);

    for (key, line) in &run_keys {
        let flag = key.replace('_', "-");
        if !flags.contains_key(&flag) && !ignored_schema.contains(&format!("run.{key}")) {
            out.push(Finding {
                file: schema.name.clone(),
                line: *line,
                check: "parity",
                msg: format!("[run] key `{key}` has no `--{flag}` pcit flag (add the flag or `// analyze: ignore(run.{key})`)"),
            });
        }
    }
    for (flag, line) in &flags {
        let key = flag.replace('-', "_");
        if !run_keys.contains_key(&key)
            && !dataset_keys.contains_key(&key)
            && !ignored_main.contains(&format!("flag {flag}"))
        {
            out.push(Finding {
                file: main.name.clone(),
                line: *line,
                check: "parity",
                msg: format!("pcit flag `--{flag}` has no `[run]`/`[dataset]` config key `{key}` (add the key or `// analyze: ignore(flag {flag})`)"),
            });
        }
    }

    // Env: every `var("QUORALL_X")` read maps to a [run] key.
    for src in env_files.iter().chain([&main, &schema]) {
        let ignored = ignore_pragmas(src);
        let mut from = 0;
        while let Some(rel) = src.raw[from..].find("var(\"QUORALL_") {
            let off = from + rel + "var(\"".len();
            let name: String = src.raw[off..].chars().take_while(|&c| is_ident(c)).collect();
            from = off + name.len();
            let key = name.trim_start_matches("QUORALL_").to_ascii_lowercase();
            if !run_keys.contains_key(&key) && !ignored.contains(&format!("env {name}")) {
                out.push(Finding {
                    file: src.name.clone(),
                    line: line_at(&src.raw, off),
                    check: "parity",
                    msg: format!("env `{name}` has no `[run]` config key `{key}` (add the key or `// analyze: ignore(env {name})`)"),
                });
            }
        }
    }
    out
}

// ---- check 5: hot-path lock/unsafe audit -------------------------------

/// Check 5: between `// analyze: hot-path begin(<name>)` and
/// `// analyze: hot-path end(<name>)`, any line containing `Mutex`,
/// `RwLock`, `.lock(` or `unsafe` must carry (or follow a line carrying)
/// an `// analyze: allow(lock)` / `// analyze: allow(unsafe)` pragma.
/// Each `(file, expected-region)` pair must actually contain its region —
/// deleting the markers is itself a finding.
pub fn check_hot_paths(regions: &[(&Src, &str)]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (src, expected) in regions {
        let mut current: Option<(String, usize)> = None;
        let mut seen: BTreeSet<String> = BTreeSet::new();
        let mut prev_raw = "";
        for (idx, (raw_line, masked_line)) in src.raw.lines().zip(src.masked.lines()).enumerate() {
            let lineno = idx + 1;
            if let Some(i) = raw_line.find("analyze: hot-path begin(") {
                let name = pragma_arg(&raw_line[i..], "analyze: hot-path begin(");
                if let Some((open, open_line)) = &current {
                    out.push(Finding {
                        file: src.name.clone(),
                        line: lineno,
                        check: "hot-path",
                        msg: format!("hot-path begin({name}) nested inside begin({open}) from line {open_line}"),
                    });
                }
                current = Some((name, lineno));
            } else if let Some(i) = raw_line.find("analyze: hot-path end(") {
                let name = pragma_arg(&raw_line[i..], "analyze: hot-path end(");
                match current.take() {
                    Some((open, _)) if open == name => {
                        seen.insert(name);
                    }
                    Some((open, open_line)) => out.push(Finding {
                        file: src.name.clone(),
                        line: lineno,
                        check: "hot-path",
                        msg: format!("hot-path end({name}) does not match begin({open}) from line {open_line}"),
                    }),
                    None => out.push(Finding {
                        file: src.name.clone(),
                        line: lineno,
                        check: "hot-path",
                        msg: format!("hot-path end({name}) without a begin"),
                    }),
                }
            } else if let Some((region, _)) = &current {
                let allowed =
                    raw_line.contains("analyze: allow(") || prev_raw.contains("analyze: allow(");
                let mut hit: Option<&str> = None;
                for t in ["Mutex", "RwLock", "unsafe"] {
                    if contains_token(masked_line, t) {
                        hit = Some(t);
                        break;
                    }
                }
                if hit.is_none() && masked_line.contains(".lock(") {
                    hit = Some(".lock(");
                }
                if let (false, Some(tok)) = (allowed, hit) {
                    out.push(Finding {
                        file: src.name.clone(),
                        line: lineno,
                        check: "hot-path",
                        msg: format!("`{tok}` in hot path `{region}` without an `// analyze: allow(lock)` / `// analyze: allow(unsafe)` pragma"),
                    });
                }
            }
            prev_raw = raw_line;
        }
        if let Some((open, open_line)) = current {
            out.push(Finding {
                file: src.name.clone(),
                line: open_line,
                check: "hot-path",
                msg: format!("hot-path begin({open}) is never closed"),
            });
        }
        if !seen.contains(*expected) {
            out.push(Finding {
                file: src.name.clone(),
                line: 1,
                check: "hot-path",
                msg: format!("expected hot-path region `{expected}` is not tagged in {} — the audit would silently cover nothing", src.name),
            });
        }
    }
    out
}

fn pragma_arg(text: &str, opener: &str) -> String {
    let tail = &text[opener.len()..];
    tail[..tail.find(')').unwrap_or(tail.len())].trim().to_string()
}

// ---- the whole tree ----------------------------------------------------

/// The dispatch sites of the real tree. Kept in one place so the CLI, the
/// tier-1 test and the docs agree on what "every variant is handled" means.
pub const LEADER_FNS: &[&str] = &["dispatch", "pump"];
pub const WORKER_FNS: &[&str] = &["worker_run"];
pub const APP_FNS: &[&str] = &[
    "poll_control",
    "ensure_blocks",
    "recv_app_where",
    "barrier",
    "recv_app_or_reroute",
    "barrier_or_reroute",
];

/// Run every check over the real sources under `rust_dir` (the directory
/// containing `Cargo.toml` and `src/`). Errors are I/O-level only; parse
/// shortfalls surface as `analyzer` findings.
pub fn analyze_tree(rust_dir: &Path) -> Result<Vec<Finding>, String> {
    let load = |rel: &str| -> Result<Src, String> {
        let path = rust_dir.join(rel);
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(Src::new(rel, text))
    };
    let messages = load("src/coordinator/messages.rs")?;
    let wire = load("src/coordinator/wire.rs")?;
    let leader = load("src/coordinator/leader.rs")?;
    let worker = load("src/coordinator/worker.rs")?;
    let app = load("src/coordinator/app.rs")?;
    let transport = load("src/coordinator/transport.rs")?;
    let matrix = load("src/util/matrix.rs")?;
    let driver = load("src/coordinator/driver.rs")?;
    let main_rs = load("src/main.rs")?;
    let schema = load("src/config/schema.rs")?;
    let logging = load("src/logging.rs")?;
    let benchkit = load("src/benchkit.rs")?;
    let prop = load("src/prop/mod.rs")?;
    let pooled = load("src/runtime/pooled.rs")?;
    let nbody = load("src/apps/nbody.rs")?;

    let mut findings = Vec::new();
    findings.extend(check_wire(&messages, &wire));
    findings.extend(check_dispatch(
        &messages,
        &[
            DispatchSite { name: "leader dispatch/pump", file: &leader, fns: LEADER_FNS },
            DispatchSite { name: "worker stash loop", file: &worker, fns: WORKER_FNS },
            DispatchSite { name: "worker task-boundary polls", file: &app, fns: APP_FNS },
        ],
    ));
    findings.extend(check_reports(&driver, &wire, &main_rs));
    findings.extend(check_parity(&main_rs, &schema, &[&driver, &logging, &benchkit, &prop]));
    findings.extend(check_hot_paths(&[
        (&transport, "recv-loop"),
        (&matrix, "matmul-nt"),
        // Intra-rank hybrid parallelism: the pooled tile helpers and the
        // two-pass n-body kernel must keep locks out of the per-tile inner
        // loops (SendPtr writes are the only audited unsafe).
        (&pooled, "pooled-tiles"),
        (&nbody, "pair-forces"),
    ]));
    Ok(findings)
}

/// Seeded-defect fixture sources, exported so both the xtask unit tests
/// and the quorall tier-1 integration test assert against one copy.
pub mod fixtures {
    pub const BAD_MESSAGES: &str = include_str!("../fixtures/bad_messages.rs");
    pub const BAD_WIRE: &str = include_str!("../fixtures/bad_wire.rs");
    pub const BAD_DISPATCH: &str = include_str!("../fixtures/bad_dispatch.rs");
    pub const BAD_HOTPATH: &str = include_str!("../fixtures/bad_hotpath.rs");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_blanks_comments_and_strings() {
        let src = "let a = 1; // Message::Fake\nlet s = \"Message::Fake {x}\";\n/* Message::Fake */ let b = 2;\n";
        let m = mask_source(src);
        assert!(!m.contains("Fake"), "masked: {m}");
        assert!(m.contains("let a = 1;"));
        assert!(m.contains("let b = 2;"));
        assert_eq!(m.lines().count(), src.lines().count());
    }

    #[test]
    fn mask_keeps_lifetimes_and_chars() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '}'; let d = '\\n'; c }\n";
        let m = mask_source(src);
        assert!(m.contains("fn f<'a>(x: &'a str)"));
        assert!(!m.contains('}') || match_brace(&m, m.find('{').unwrap()).is_some());
    }

    #[test]
    fn mask_blanks_raw_strings() {
        let src = "let d = r#\"[run]\nranks = 4 }\"#;\nlet e = 5;\n";
        let m = mask_source(src);
        assert!(!m.contains("ranks"));
        assert!(m.contains("let e = 5;"));
        assert_eq!(m.lines().count(), src.lines().count());
    }

    #[test]
    fn enum_variants_and_struct_fields_extract() {
        let src = Src::new(
            "t.rs",
            "/// Doc { with braces }\npub enum Message {\n    /// doc\n    Alpha,\n    Beta { id: usize, v: Vec<(usize, f32)> },\n    Gamma(Vec<[f64; 3]>),\n}\npub struct S {\n    pub a: usize,\n    pub b: Vec<(usize, usize)>,\n}\n",
        );
        let vs: Vec<String> = enum_variants(&src, "Message").into_iter().map(|(v, _)| v).collect();
        assert_eq!(vs, ["Alpha", "Beta", "Gamma"]);
        let fs: Vec<String> = struct_fields(&src, "S").into_iter().map(|(f, _)| f).collect();
        assert_eq!(fs, ["a", "b"]);
    }

    #[test]
    fn fn_body_extracts_decl_through_close() {
        let src = Src::new(
            "t.rs",
            "fn other() {}\n\npub fn target(x: usize) -> usize {\n    let y = \"}\";\n    x + y.len()\n}\nfn after() {}\n",
        );
        let b = fn_body(&src, "target").expect("found");
        assert_eq!(b.decl_line, 3);
        assert!(b.masked.contains("x + y.len()"));
        assert!(!b.masked.contains("after"));
    }

    #[test]
    fn clean_codec_has_no_findings() {
        let messages = Src::new(
            "messages.rs",
            "pub enum Message { Alpha, Beta { id: usize } }\npub enum Payload { Tile(Vec<f32>) }\n",
        );
        let wire = Src::new(
            "wire.rs",
            r#"
pub fn encode_message(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Alpha => put_u8(&mut out, 0),
        Message::Beta { id } => {
            put_u8(&mut out, 1);
            put_u64(&mut out, *id as u64);
        }
    }
    out
}
pub fn take_message(r: &mut Reader) -> Message {
    match take_u8(r) {
        0 => Message::Alpha,
        1 => Message::Beta { id: take_u64(r) as usize },
        t => panic!("tag {t}"),
    }
}
fn put_payload(out: &mut Vec<u8>, p: &Payload) {
    match p {
        Payload::Tile(v) => { put_u8(out, 0); }
    }
}
fn take_payload(r: &mut Reader) -> Payload {
    match take_u8(r) {
        0 => Payload::Tile(vec![]),
        t => panic!("tag {t}"),
    }
}
fn every_message_variant_round_trips_framed() {
    let _ = Message::Alpha;
    let _ = Message::Beta { id: 7 };
    let _ = Payload::Tile(vec![1.0]);
}
"#,
        );
        let findings = check_wire(&messages, &wire);
        assert!(findings.is_empty(), "unexpected:\n{}", render(&findings));
    }
}
