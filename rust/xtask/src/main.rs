//! `cargo xtask analyze` — run the protocol conformance pass over the
//! tree and exit non-zero on any finding. See ../src/lib.rs for what the
//! five checks enforce and ROADMAP.md for why they exist.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                if i + 1 >= args.len() {
                    eprintln!("--root requires a path");
                    return ExitCode::FAILURE;
                }
                root = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            "--help" | "-h" => {
                print_help();
                return ExitCode::SUCCESS;
            }
            other if cmd.is_none() => {
                cmd = Some(other.to_string());
                i += 1;
            }
            other => {
                eprintln!("unknown argument: {other}");
                return ExitCode::FAILURE;
            }
        }
    }
    match cmd.as_deref() {
        Some("analyze") => analyze(&root),
        Some(other) => {
            eprintln!("unknown xtask command: {other}");
            print_help();
            ExitCode::FAILURE
        }
        None => {
            print_help();
            ExitCode::FAILURE
        }
    }
}

fn analyze(root: &std::path::Path) -> ExitCode {
    match xtask::analyze_tree(root) {
        Ok(findings) if findings.is_empty() => {
            println!(
                "analyze: wire / dispatch / reports / parity / hot-path checks clean under {}",
                root.display()
            );
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            eprintln!("{}", xtask::render(&findings));
            eprintln!("analyze: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("analyze: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "usage: cargo xtask analyze [--root <rust-dir>]\n\n\
         Static protocol-conformance checks over the coordinator sources:\n\
         wire codec arms, dispatch coverage, report-field drift, CLI/config/env\n\
         parity, and the hot-path lock/unsafe audit. Non-zero exit on findings."
    );
}
