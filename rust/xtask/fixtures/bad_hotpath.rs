//! Analyzer fixture: a tagged hot path containing a Mutex acquisition
//! without an allow pragma (the seeded defect) and an unsafe block that is
//! correctly pragma'd. Never compiled — parsed only.

// analyze: hot-path begin(recv-loop)
pub fn recv(&self) -> Envelope {
    let guard = self.queue.lock().unwrap(); // seeded hot-path Mutex defect
    // analyze: allow(unsafe): fixture — pointer read is pre-validated
    let v = unsafe { *self.ptr };
    drop(guard);
    make_envelope(v)
}
// analyze: hot-path end(recv-loop)
