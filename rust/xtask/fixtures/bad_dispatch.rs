//! Analyzer fixture: a dispatch loop that silently drops a variant.
//! Gamma is neither matched nor pragma'd (the seeded defect); Delta is
//! legitimately exempted by pragma. Never compiled — parsed only.

pub fn dispatch(&mut self, env: Envelope) {
    match env.msg {
        Message::Alpha => self.on_alpha(),
        Message::Beta { id } => self.on_beta(id),
        other => panic!("unexpected {other:?}"),
    }
}

// analyze: ignore(Delta): fixture — Delta never reaches this site
