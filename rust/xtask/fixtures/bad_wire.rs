//! Analyzer fixture: a wire codec with three seeded defects —
//! a duplicate wire tag (Gamma encodes as Beta's tag), a missing decode
//! arm (Gamma), and a round-trip coverage gap (Delta). Never compiled.

pub fn encode_message(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Alpha => put_u8(&mut out, 0),
        Message::Beta { id } => {
            put_u8(&mut out, 1);
            put_u64(&mut out, *id as u64);
        }
        Message::Gamma(x) => put_u8(&mut out, 1), // seeded duplicate-tag defect
        Message::Delta => put_u8(&mut out, 3),
    }
    out
}

pub fn take_message(r: &mut Reader) -> Message {
    match take_u8(r) {
        0 => Message::Alpha,
        1 => Message::Beta { id: take_u64(r) as usize },
        3 => Message::Delta,
        t => panic!("unknown tag {t}"),
    }
    // seeded defect: Message::Gamma has no decode arm (mentioned only in
    // this comment, which the masked scan must not count).
}

pub fn put_payload(out: &mut Vec<u8>, p: &Payload) {
    match p {
        Payload::Tile(v) => {
            put_u8(out, 0);
            put_f32s(out, v);
        }
    }
}

pub fn take_payload(r: &mut Reader) -> Payload {
    match take_u8(r) {
        0 => Payload::Tile(take_f32s(r)),
        t => panic!("unknown payload tag {t}"),
    }
}

mod tests {
    fn every_message_variant_round_trips_framed() {
        let _ = Message::Alpha;
        let _ = Message::Beta { id: 7 };
        let _ = Message::Gamma(9);
        let _ = Payload::Tile(vec![1.0]);
        // seeded defect: Message::Delta is never constructed here.
    }
}
