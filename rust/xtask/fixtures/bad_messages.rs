//! Analyzer fixture: the message enums the seeded-defect fixtures
//! (bad_wire.rs, bad_dispatch.rs) mishandle. Never compiled — parsed only.

pub enum Message {
    /// Clean: encoded, decoded, round-tripped, dispatched.
    Alpha,
    /// Clean codec; dispatched.
    Beta { id: usize },
    /// Defective: duplicate wire tag, no decode arm, not dispatched.
    Gamma(u64),
    /// Defective: missing from the round-trip test; pragma'd at dispatch.
    Delta,
}

pub enum Payload {
    /// Clean on every axis.
    Tile(Vec<f32>),
}
