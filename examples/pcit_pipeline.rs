//! End-to-end driver (DESIGN.md: the E2E validation run recorded in
//! EXPERIMENTS.md): full PCIT gene-network pipeline on a realistic
//! synthetic dataset, exercising all layers —
//!
//!   synthetic data → quorum construction → leader/worker cluster →
//!   correlation tiles (native or AOT/XLA backend) → ring exchange →
//!   PCIT elimination → network, validated against single-node PCIT
//!   and against the planted ground-truth modules.
//!
//! Run: `cargo run --release --example pcit_pipeline [-- --xla] [-- --large]`

use quorall::config::{BackendKind, PcitMode, RunConfig};
use quorall::coordinator::{run_distributed_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::metrics::Table;
use quorall::util::bytes::format_bytes;
use quorall::util::timer::format_secs;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let use_xla = args.iter().any(|a| a == "--xla");
    let large = args.iter().any(|a| a == "--large");

    let spec = if large {
        SyntheticSpec { genes: 1536, samples: 48, modules: 24, noise: 0.6, seed: 2016 }
    } else {
        SyntheticSpec { genes: 512, samples: 48, modules: 12, noise: 0.6, seed: 2016 }
    };
    println!(
        "dataset: N = {} genes × M = {} samples, {} planted modules, seed {}",
        spec.genes, spec.samples, spec.modules, spec.seed
    );
    let dataset = ExpressionDataset::generate(spec);

    // Single-node baseline (the paper's left-most bar).
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let single = run_single_node(&dataset, threads, None);
    println!(
        "single-node ({} threads): {} edges in {} | memory {}\n(testbed has {} core(s): distributed wall clock serializes ranks; 'crit.path' = slowest rank's compute, the cluster-time measure)",
        threads,
        single.network.n_edges(),
        format_secs(single.wall_secs),
        format_bytes(single.logical_bytes),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );

    let backend = if use_xla { BackendKind::Xla } else { BackendKind::Native };
    let exec = quorall::runtime::executor_for(backend, std::path::Path::new("artifacts"))?;
    println!("tile backend: {}", exec.name());

    let mut t = Table::new(
        "distributed PCIT scaling (quorum-exact)",
        &["ranks", "k", "wall(1-core)", "crit.path", "cp speedup", "mem/rank", "mem reduction", "comm", "edges", "identical"],
    );
    for ranks in [4usize, 8, 16] {
        let cfg = RunConfig {
            ranks,
            mode: PcitMode::QuorumExact,
            backend,
            ..RunConfig::default()
        };
        let rep = run_distributed_pcit(&cfg, &dataset, exec.clone())?;
        let identical = rep.network.same_edges(&single.network);
        t.row(vec![
            ranks.to_string(),
            rep.quorum_size.to_string(),
            format_secs(rep.wall_secs),
            format_secs(rep.critical_path_secs),
            format!("{:.2}x", single.wall_secs / rep.critical_path_secs),
            format_bytes(rep.peak_bytes_per_rank),
            format!("{:.0}%", 100.0 * (1.0 - rep.peak_bytes_per_rank as f64 / single.logical_bytes as f64)),
            format_bytes(rep.total_comm_bytes),
            rep.network.n_edges().to_string(),
            if identical { "yes" } else { "NO" }.to_string(),
        ]);
        anyhow::ensure!(identical, "quorum-exact network must match single-node");
    }
    println!("{}", t.render());

    // Ground-truth validation: strong surviving edges should be intra-module.
    let cfg = RunConfig { ranks: 8, mode: PcitMode::QuorumExact, backend, ..RunConfig::default() };
    let rep = run_distributed_pcit(&cfg, &dataset, exec)?;
    let precision = rep.network.module_precision(&dataset, 0.5);
    println!(
        "planted-module precision of strong edges (|r| >= 0.5): {:.1}%",
        100.0 * precision
    );
    anyhow::ensure!(precision > 0.8, "network must recover planted structure");
    println!("\nE2E pipeline complete: all layers compose ✓");
    Ok(())
}
