//! Quickstart: build a cyclic quorum set, verify the paper's properties,
//! and run a small distributed all-pairs computation.
//!
//! Run: `cargo run --release --example quickstart`

use quorall::config::{PcitMode, RunConfig};
use quorall::coordinator::{run_distributed_pcit, run_single_node};
use quorall::data::synthetic::{ExpressionDataset, SyntheticSpec};
use quorall::quorum::CyclicQuorumSet;
use quorall::runtime::NativeBackend;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // --- 1. Quorums (paper §3-4): O(sqrt(P))-sized sets covering all pairs.
    let p = 7;
    let q = CyclicQuorumSet::for_processes(p)?;
    println!("P = {p} processes, base difference set A = {:?}", q.base_set());
    for i in 0..p {
        println!("  S_{i} = {:?}", q.quorum(i));
    }
    assert!(q.verify_all_pairs_property(), "Theorem 1 holds");
    println!("every pair of datasets shares at least one quorum ✓\n");

    // --- 2. Distributed PCIT (paper §5) on a small synthetic dataset.
    let dataset = ExpressionDataset::generate(SyntheticSpec {
        genes: 256,
        samples: 32,
        modules: 8,
        noise: 0.5,
        seed: 7,
    });
    let cfg = RunConfig { ranks: p, mode: PcitMode::QuorumExact, ..RunConfig::default() };
    let report = run_distributed_pcit(&cfg, &dataset, Arc::new(NativeBackend::new()))?;
    println!(
        "distributed PCIT: {} significant edges across {} genes ({} ranks, k = {})",
        report.network.n_edges(),
        dataset.genes(),
        p,
        report.quorum_size
    );

    // --- 3. The headline check: identical to the single-node algorithm.
    let single = run_single_node(&dataset, 4, None);
    assert!(report.network.same_edges(&single.network));
    println!("network identical to single-node PCIT ✓");
    println!(
        "memory per rank: {} vs single-node {}",
        quorall::util::bytes::format_bytes(report.peak_bytes_per_rank),
        quorall::util::bytes::format_bytes(single.logical_bytes),
    );
    Ok(())
}
