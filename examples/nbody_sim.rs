//! N-body demo: quorum-decomposed force computation driving a leapfrog
//! integrator, with energy-conservation and decomposition-equivalence
//! checks (the paper's §1 molecular-dynamics motivation).
//!
//! Run: `cargo run --release --example nbody_sim`

use quorall::apps::nbody::{forces_direct, forces_quorum, simulate, Bodies};
use quorall::pool::ThreadPool;
use quorall::util::timer::{format_secs, Stopwatch};

fn main() -> anyhow::Result<()> {
    let n = 512;
    let ranks = 8;
    let pool = ThreadPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    println!("n-body: {n} bodies, {ranks} simulated ranks");

    // Equivalence: quorum decomposition computes the same forces.
    let bodies = Bodies::random(n, 2016);
    let direct = forces_direct(&bodies);
    let quorum = forces_quorum(&bodies, ranks, &pool)?;
    let max_err = direct
        .iter()
        .zip(&quorum)
        .flat_map(|(a, b)| (0..3).map(move |d| (a[d] - b[d]).abs()))
        .fold(0.0f64, f64::max);
    println!("max |F_direct - F_quorum| = {max_err:.3e} ✓");
    anyhow::ensure!(max_err < 1e-8, "decompositions must agree");

    // Dynamics: energy drift over a short run.
    let mut sim_bodies = Bodies::random(n, 2016);
    let e0 = sim_bodies.total_energy();
    let sw = Stopwatch::start();
    let steps = 100;
    let drift = simulate(&mut sim_bodies, ranks, steps, 5e-5, &pool)?;
    println!(
        "{steps} leapfrog steps in {} | E0 = {e0:.4} | relative energy drift = {drift:.2e}",
        format_secs(sw.elapsed_secs())
    );
    anyhow::ensure!(drift < 0.02, "symplectic integration should conserve energy (drift {drift})");
    println!("n-body pipeline ✓");
    Ok(())
}
