//! Biometric-style similarity search (paper §1, face-recognition use case
//! [2]): all-pairs cosine similarity over feature vectors, computed with
//! the quorum decomposition, then a nearest-duplicate report.
//!
//! Run: `cargo run --release --example similarity_search [-- --xla]`

use quorall::apps::similarity::{normalize_rows, similarity_direct, similarity_quorum, top_pairs};
use quorall::config::BackendKind;
use quorall::pool::ThreadPool;
use quorall::util::prng::Rng;
use quorall::util::Matrix;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");
    let n = 400; // subjects
    let dim = 64; // embedding dimension
    let ranks = 8;

    // Synthesize embeddings with planted near-duplicate pairs.
    let mut rng = Rng::new(99);
    let mut features = Matrix::from_fn(n, dim, |_, _| rng.normal_f32());
    let mut planted = Vec::new();
    for dup in 0..10 {
        let a = dup * 37 % n;
        let b = (a + n / 2) % n;
        // b becomes a noisy copy of a.
        let mut row = features.row(a).to_vec();
        for v in &mut row {
            *v += 0.08 * rng.normal_f32();
        }
        features.row_mut(b).copy_from_slice(&row);
        planted.push((a.min(b), a.max(b)));
    }

    let backend = if use_xla { BackendKind::Xla } else { BackendKind::Native };
    let exec = quorall::runtime::executor_for(backend, std::path::Path::new("artifacts"))?;
    let pool = ThreadPool::new(4);
    println!("similarity: {n} embeddings × {dim} dims, {ranks} ranks, backend = {}", exec.name());

    let sim = similarity_quorum(&features, ranks, &exec, &pool)?;
    let direct = similarity_direct(&features);
    let diff = sim.max_abs_diff(&direct);
    println!("max |distributed - direct| = {diff:.2e} ✓");
    anyhow::ensure!(diff < 1e-4);

    let top = top_pairs(&sim, 10);
    println!("top-10 most similar pairs:");
    let mut hits = 0;
    for (x, y, s) in &top {
        let is_planted = planted.contains(&(*x.min(y), *x.max(y)));
        if is_planted {
            hits += 1;
        }
        println!("  ({x:3}, {y:3})  sim = {s:.4}  {}", if is_planted { "[planted duplicate]" } else { "" });
    }
    println!("recovered {hits}/10 planted duplicates in the top-10");
    anyhow::ensure!(hits >= 9, "nearly all planted duplicates must surface");

    // Crosscheck normalization path.
    let z = normalize_rows(&features);
    let norm0: f32 = z.row(0).iter().map(|v| v * v).sum();
    anyhow::ensure!((norm0 - 1.0).abs() < 1e-5);
    println!("similarity pipeline ✓");
    Ok(())
}
