"""Pallas corr_chunk vs the pure-jnp oracle (and numpy), across shapes."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.correlation import TILE_A, TILE_B, corr_chunk
from compile.kernels.ref import corr_chunk_ref, standardize_rows_ref


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("a,b,m", [(64, 64, 8), (64, 128, 32), (128, 64, 128), (128, 128, 256)])
def test_matches_ref_shapes(a, b, m):
    rng = np.random.default_rng(1234 + a + b + m)
    za, zb = rand(rng, a, m), rand(rng, b, m)
    got = corr_chunk(jnp.asarray(za), jnp.asarray(zb))
    want = corr_chunk_ref(jnp.asarray(za), jnp.asarray(zb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matches_numpy_float64():
    rng = np.random.default_rng(7)
    za, zb = rand(rng, 64, 48), rand(rng, 64, 48)
    got = np.asarray(corr_chunk(jnp.asarray(za), jnp.asarray(zb)))
    want = za.astype(np.float64) @ zb.astype(np.float64).T
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    ta=st.integers(min_value=1, max_value=3),
    tb=st.integers(min_value=1, max_value=3),
    m=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_sweep(ta, tb, m, seed):
    a, b = ta * TILE_A, tb * TILE_B
    rng = np.random.default_rng(seed)
    za, zb = rand(rng, a, m), rand(rng, b, m)
    got = corr_chunk(jnp.asarray(za), jnp.asarray(zb))
    want = corr_chunk_ref(jnp.asarray(za), jnp.asarray(zb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_standardized_inputs_give_unit_diag():
    rng = np.random.default_rng(11)
    x = rand(rng, 64, 40)
    z = standardize_rows_ref(jnp.asarray(x))
    c = corr_chunk(z, z)
    np.testing.assert_allclose(np.asarray(jnp.diag(c)), np.ones(64), rtol=1e-4, atol=1e-4)
    assert np.all(np.abs(np.asarray(c)) <= 1.0 + 1e-4)


def test_rejects_unpadded_shapes():
    za = jnp.zeros((63, 16))
    zb = jnp.zeros((64, 16))
    with pytest.raises(AssertionError):
        corr_chunk(za, zb)


def test_zero_padding_is_identity():
    # Zero-padding M must not change the result (the Rust runtime relies on
    # this to chunk the contraction).
    rng = np.random.default_rng(13)
    za, zb = rand(rng, 64, 30), rand(rng, 64, 30)
    full = np.asarray(corr_chunk(jnp.asarray(za), jnp.asarray(zb)))
    zap = np.pad(za, ((0, 0), (0, 34)))
    zbp = np.pad(zb, ((0, 0), (0, 34)))
    padded = np.asarray(corr_chunk(jnp.asarray(zap), jnp.asarray(zbp)))
    np.testing.assert_allclose(full, padded, rtol=1e-6, atol=1e-6)
