"""L2 model composition: standardize → corr tiles → clamp; shapes + values."""

import jax.numpy as jnp
import numpy as np

from compile.model import corr_model
from compile.kernels.ref import standardize_rows_ref


def test_corr_model_matches_numpy():
    rng = np.random.default_rng(17)
    xa = rng.standard_normal((64, 40)).astype(np.float32)
    xb = rng.standard_normal((64, 40)).astype(np.float32)
    got = np.asarray(corr_model(jnp.asarray(xa), jnp.asarray(xb)))

    def std(x):
        c = x - x.mean(axis=1, keepdims=True)
        n = np.sqrt((c * c).sum(axis=1, keepdims=True))
        return np.divide(c, n, out=np.zeros_like(c), where=n > 0)

    want = std(xa.astype(np.float64)) @ std(xb.astype(np.float64)).T
    np.testing.assert_allclose(got, np.clip(want, -1, 1), rtol=1e-4, atol=1e-4)
    assert np.all(np.abs(got) <= 1.0)


def test_constant_rows_zero():
    xa = np.ones((64, 16), dtype=np.float32)
    xb = np.random.default_rng(1).standard_normal((64, 16)).astype(np.float32)
    got = np.asarray(corr_model(jnp.asarray(xa), jnp.asarray(xb)))
    np.testing.assert_array_equal(got, np.zeros((64, 64), dtype=np.float32))


def test_standardize_ref_props():
    rng = np.random.default_rng(23)
    x = rng.standard_normal((10, 30)).astype(np.float32)
    z = np.asarray(standardize_rows_ref(jnp.asarray(x)))
    np.testing.assert_allclose(z.mean(axis=1), np.zeros(10), atol=1e-6)
    np.testing.assert_allclose((z * z).sum(axis=1), np.ones(10), rtol=1e-5)
