"""Pallas pcit_chunk vs the pure-jnp oracle and a scalar python reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pcit import EPS_GUARD, TILE_A, TILE_B, ZSTEP, pcit_chunk
from compile.kernels.ref import pcit_chunk_ref


def corr_like(rng, *shape):
    """Random values in (-1, 1) like correlations."""
    return (rng.uniform(-0.98, 0.98, shape)).astype(np.float32)


def scalar_trio(rxy, rxz, ryz):
    """Direct scalar transcription of quorall::pcit::trio_eliminates."""
    dxy, dxz, dyz = 1 - rxy * rxy, 1 - rxz * rxz, 1 - ryz * ryz
    if dxy < EPS_GUARD or dxz < EPS_GUARD or dyz < EPS_GUARD:
        return False
    if abs(rxy) < EPS_GUARD or abs(rxz) < EPS_GUARD or abs(ryz) < EPS_GUARD:
        return False
    pxy = (rxy - rxz * ryz) / np.sqrt(dxz * dyz)
    pxz = (rxz - rxy * ryz) / np.sqrt(dxy * dyz)
    pyz = (ryz - rxy * rxz) / np.sqrt(dxy * dxz)
    eps = (pxy / rxy + pxz / rxz + pyz / ryz) / 3.0
    return abs(rxy) < abs(eps * rxz) and abs(rxy) < abs(eps * ryz)


@pytest.mark.parametrize("a,b,z", [(64, 64, 8), (64, 64, 64), (128, 64, 128), (64, 128, 16)])
def test_matches_ref(a, b, z):
    rng = np.random.default_rng(a * 1000 + b + z)
    cxy = corr_like(rng, a, b)
    rxz = corr_like(rng, a, z)
    ryz = corr_like(rng, b, z)
    got = pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    want = pcit_chunk_ref(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matches_scalar_reference():
    rng = np.random.default_rng(42)
    a, b, z = 64, 64, 8
    cxy = corr_like(rng, a, b)
    rxz = corr_like(rng, a, z)
    ryz = corr_like(rng, b, z)
    got = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz)))
    # Spot-check a grid of pairs against the scalar rule.
    for i in range(0, a, 7):
        for j in range(0, b, 11):
            want = any(scalar_trio(float(cxy[i, j]), float(rxz[i, t]), float(ryz[j, t])) for t in range(z))
            assert bool(got[i, j]) == want, f"pair ({i},{j})"


def test_degenerate_mediators_never_eliminate():
    a = b = 64
    z = ZSTEP
    # Strong direct edge, mediators exactly ±1 or 0 → all guarded out.
    cxy = np.full((a, b), 0.9, dtype=np.float32)
    rxz = np.zeros((a, z), dtype=np.float32)
    ryz = np.ones((b, z), dtype=np.float32)
    got = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz)))
    assert not got.any()


def test_mediated_edge_eliminated():
    # |r_xy| well below the indirect path r_xz·r_yz → eliminated.
    # (PCIT is conservative: r_xy close to r_xz·r_yz is kept, see the
    # matching rust unit test quorall::pcit::tests::mediated_edge_eliminated.)
    a = b = 64
    z = ZSTEP
    cxy = np.full((a, b), 0.1, dtype=np.float32)
    rxz = np.full((a, z), 0.6, dtype=np.float32)
    ryz = np.full((b, z), 0.6, dtype=np.float32)
    got = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz)))
    assert got.all()
    # Near-mediated strong edge survives.
    cxy2 = np.full((a, b), 0.74, dtype=np.float32)
    rxz2 = np.full((a, z), 0.9, dtype=np.float32)
    ryz2 = np.full((b, z), 0.9, dtype=np.float32)
    got2 = np.asarray(pcit_chunk(jnp.asarray(cxy2), jnp.asarray(rxz2), jnp.asarray(ryz2)))
    assert not got2.any()


@settings(max_examples=20, deadline=None)
@given(
    ta=st.integers(min_value=1, max_value=2),
    tb=st.integers(min_value=1, max_value=2),
    zm=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_sweep(ta, tb, zm, seed):
    a, b, z = ta * TILE_A, tb * TILE_B, zm * ZSTEP
    rng = np.random.default_rng(seed)
    cxy = corr_like(rng, a, b)
    rxz = corr_like(rng, a, z)
    ryz = corr_like(rng, b, z)
    got = pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    want = pcit_chunk_ref(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_padding_z_is_identity():
    # Zero mediator columns never eliminate — the Rust runtime pads with 0.
    rng = np.random.default_rng(5)
    cxy = corr_like(rng, 64, 64)
    rxz = corr_like(rng, 64, 16)
    ryz = corr_like(rng, 64, 16)
    base = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz)))
    rxz_p = np.pad(rxz, ((0, 0), (0, 48)))
    ryz_p = np.pad(ryz, ((0, 0), (0, 48)))
    padded = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz_p), jnp.asarray(ryz_p)))
    np.testing.assert_array_equal(base, padded)


def test_unit_diagonal_self_masks():
    # Mediator columns equal to the x gene itself (r = 1) are inert.
    rng = np.random.default_rng(9)
    cxy = corr_like(rng, 64, 64)
    rxz = corr_like(rng, 64, ZSTEP)
    ryz = corr_like(rng, 64, ZSTEP)
    rxz[:, 0] = 1.0  # z == x
    base = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz)))
    rxz2 = rxz.copy()
    rxz2[:, 0] = 0.0  # equally inert
    alt = np.asarray(pcit_chunk(jnp.asarray(cxy), jnp.asarray(rxz2), jnp.asarray(ryz)))
    np.testing.assert_array_equal(base, alt)
