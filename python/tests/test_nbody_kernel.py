"""Pallas nbody_tile vs the pure-jnp direct-force oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.nbody import SOFTENING, TILE_A, nbody_tile
from compile.kernels.ref import nbody_forces_ref


def bodies(rng, n):
    pos = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    mass = rng.uniform(0.5, 1.5, (n,)).astype(np.float32)
    return pos, mass


def pack(pos, mass):
    pos4 = np.pad(pos, ((0, 0), (0, 1))).astype(np.float32)
    m1 = mass[:, None].astype(np.float32)
    return jnp.asarray(pos4), jnp.asarray(m1)


def test_self_block_matches_ref():
    rng = np.random.default_rng(3)
    pos, mass = bodies(rng, TILE_A)
    want = np.asarray(nbody_forces_ref(jnp.asarray(pos), jnp.asarray(mass), SOFTENING))
    pa, ma = pack(pos, mass)
    got = np.asarray(nbody_tile(pa, ma, pa, ma))[:, :3]
    # Self-interaction: diff = 0 numerator kills the i == i term exactly,
    # so the full block equals the reference (which masks the diagonal).
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cross_blocks_sum_to_direct():
    rng = np.random.default_rng(5)
    n = 2 * TILE_A
    pos, mass = bodies(rng, n)
    want = np.asarray(nbody_forces_ref(jnp.asarray(pos), jnp.asarray(mass), SOFTENING))
    # Split into two blocks; total force on block 0 = self + cross.
    p0, m0 = pack(pos[:TILE_A], mass[:TILE_A])
    p1, m1 = pack(pos[TILE_A:], mass[TILE_A:])
    f_self = np.asarray(nbody_tile(p0, m0, p0, m0))[:, :3]
    f_cross = np.asarray(nbody_tile(p0, m0, p1, m1))[:, :3]
    np.testing.assert_allclose(f_self + f_cross, want[:TILE_A], rtol=2e-4, atol=2e-4)


def test_zero_mass_padding_inert():
    rng = np.random.default_rng(7)
    pos, mass = bodies(rng, TILE_A)
    pa, ma = pack(pos, mass)
    got = np.asarray(nbody_tile(pa, ma, pa, ma))[:, :3]
    # Pad source block with zero-mass bodies: forces unchanged.
    pos_b = np.vstack([pos, rng.uniform(0, 1, (TILE_A, 3)).astype(np.float32)])
    mass_b = np.concatenate([mass, np.zeros(TILE_A, dtype=np.float32)])
    pb, mb = pack(pos_b, mass_b)
    padded = np.asarray(nbody_tile(pa, ma, pb, mb))[:, :3]
    np.testing.assert_allclose(got, padded, rtol=1e-5, atol=1e-6)


def test_newton_third_law():
    rng = np.random.default_rng(9)
    pos, mass = bodies(rng, TILE_A)
    pa, ma = pack(pos[: TILE_A // 2 * 2], mass)
    p0, m0 = pack(pos[:TILE_A], mass[:TILE_A])
    del pa, ma
    rng2 = np.random.default_rng(10)
    pos_b, mass_b = bodies(rng2, TILE_A)
    p1, m1 = pack(pos_b, mass_b)
    f01 = np.asarray(nbody_tile(p0, m0, p1, m1))[:, :3]
    f10 = np.asarray(nbody_tile(p1, m1, p0, m0))[:, :3]
    np.testing.assert_allclose(f01.sum(axis=0), -f10.sum(axis=0), rtol=1e-3, atol=1e-4)


def test_rejects_unpadded():
    rng = np.random.default_rng(11)
    pos, mass = bodies(rng, TILE_A - 1)
    pa, ma = pack(pos, mass)
    with pytest.raises(AssertionError):
        nbody_tile(pa, ma, pa, ma)
