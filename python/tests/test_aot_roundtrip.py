"""AOT pipeline: entry points lower to HLO text; manifest is well-formed.

The Rust-side load/execute is covered by rust/tests/integration_runtime.rs;
here we verify the python half standalone (fast, no artifacts needed).
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_corr_entry_lowers_to_hlo_text():
    lowered = aot.lower_entry(model.corr_entry, [(aot.CORR_A, aot.CORR_M), (aot.CORR_B, aot.CORR_M)])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[128,128]" in text


def test_pcit_entry_lowers_to_hlo_text():
    lowered = aot.lower_entry(
        model.pcit_entry,
        [(aot.PCIT_A, aot.PCIT_B), (aot.PCIT_A, aot.PCIT_Z), (aot.PCIT_B, aot.PCIT_Z)],
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_nbody_entry_lowers_to_hlo_text():
    lowered = aot.lower_entry(
        model.nbody_entry,
        [(aot.NBODY_A, 4), (aot.NBODY_A, 1), (aot.NBODY_B, 4), (aot.NBODY_B, 1)],
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_entries_execute_like_refs():
    # The jitted entry (what gets lowered) must agree with the oracle.
    from compile.kernels.ref import corr_chunk_ref, pcit_chunk_ref

    rng = np.random.default_rng(3)
    za = rng.standard_normal((aot.CORR_A, aot.CORR_M)).astype(np.float32)
    zb = rng.standard_normal((aot.CORR_B, aot.CORR_M)).astype(np.float32)
    (got,) = jax.jit(model.corr_entry)(jnp.asarray(za), jnp.asarray(zb))
    want = corr_chunk_ref(jnp.asarray(za), jnp.asarray(zb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    cxy = rng.uniform(-0.9, 0.9, (aot.PCIT_A, aot.PCIT_B)).astype(np.float32)
    rxz = rng.uniform(-0.9, 0.9, (aot.PCIT_A, aot.PCIT_Z)).astype(np.float32)
    ryz = rng.uniform(-0.9, 0.9, (aot.PCIT_B, aot.PCIT_Z)).astype(np.float32)
    (flags,) = jax.jit(model.pcit_entry)(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    want = pcit_chunk_ref(jnp.asarray(cxy), jnp.asarray(rxz), jnp.asarray(ryz))
    np.testing.assert_array_equal(np.asarray(flags), np.asarray(want))


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        timeout=600,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert set(manifest["kernels"]) == {"corr_chunk", "pcit_chunk", "nbody_chunk"}
    for spec in manifest["kernels"].values():
        assert (out / spec["file"]).exists()
        assert (out / spec["file"]).read_text().startswith("HloModule")
