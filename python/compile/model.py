"""L2 — the JAX compute graph composing the L1 Pallas kernels.

The distributed PCIT data flow (DESIGN.md §7) is tile-structured; what the
AOT artifacts export are the static-shape entry points the Rust runtime
calls:

* ``corr_entry``      — (A, M) × (B, M) → (A, B) partial dot products
                        (accumulated + clamped by the caller across M
                        chunks, keeping the artifact static).
* ``pcit_entry``      — (A, B) × (A, Z) × (B, Z) → (A, B) elimination flags
                        for one mediator chunk (OR-accumulated by caller).
* ``corr_model``      — the full L2 composition used by python tests:
                        raw expression rows → standardize → tiled corr →
                        clamp. Demonstrates the whole graph lowers and
                        fuses; not exported (dynamic N×M).
* ``nbody_entry``     — (A, 4)+(A, 1) × (B, 4)+(B, 1) → (A, 4) force tile.

Everything here runs at build time only.
"""

import jax.numpy as jnp

from compile.kernels.correlation import corr_chunk
from compile.kernels.nbody import nbody_tile
from compile.kernels.pcit import pcit_chunk
from compile.kernels.ref import standardize_rows_ref


def corr_entry(za, zb):
    """AOT entry: one correlation chunk (pure matmul tile)."""
    return (corr_chunk(za, zb),)


def pcit_entry(cxy, rxz, ryz):
    """AOT entry: one PCIT elimination chunk."""
    return (pcit_chunk(cxy, rxz, ryz),)


def nbody_entry(pos_a, mass_a, pos_b, mass_b):
    """AOT entry: one n-body force tile."""
    return (nbody_tile(pos_a, mass_a, pos_b, mass_b),)


def corr_model(x_a, x_b):
    """Full L2 path: raw rows → standardized → correlation block, clamped.

    Used by the python test suite to check the composed graph; the Rust
    coordinator performs the same standardize step natively (O(NM), cold
    path) and calls ``corr_entry`` for the hot tiles.
    """
    za = standardize_rows_ref(x_a)
    zb = standardize_rows_ref(x_b)
    c = corr_chunk(za, zb)
    return jnp.clip(c, -1.0, 1.0)
