"""AOT lowering: JAX/Pallas entry points → HLO text + manifest.json.

HLO **text** is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Static artifact shapes (see DESIGN.md §4 for the VMEM budget).
CORR_A = 128
CORR_B = 128
CORR_M = 128
PCIT_A = 128
PCIT_B = 128
PCIT_Z = 128
NBODY_A = 128
NBODY_B = 128


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, arg_shapes):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in arg_shapes]
    return jax.jit(fn).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = {
        "corr_chunk": (
            model.corr_entry,
            [(CORR_A, CORR_M), (CORR_B, CORR_M)],
            {"a": CORR_A, "b": CORR_B, "m": CORR_M},
        ),
        "pcit_chunk": (
            model.pcit_entry,
            [(PCIT_A, PCIT_B), (PCIT_A, PCIT_Z), (PCIT_B, PCIT_Z)],
            {"a": PCIT_A, "b": PCIT_B, "z": PCIT_Z},
        ),
        "nbody_chunk": (
            model.nbody_entry,
            [(NBODY_A, 4), (NBODY_A, 1), (NBODY_B, 4), (NBODY_B, 1)],
            {"a": NBODY_A, "b": NBODY_B},
        ),
    }

    manifest = {"version": 1, "kernels": {}}
    for name, (fn, shapes, dims) in entries.items():
        lowered = lower_entry(fn, shapes)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["kernels"][name] = {"file": fname, **dims}
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
