"""Pallas n-body force-tile kernel (L1) — the motivating-domain example.

Computes partial forces of one block pair: forces exerted on bodies in
block A by bodies in block B (softened gravity). Block-diagonal self
interaction is masked by the caller passing identical blocks and the
kernel zeroing the i == j (by-position) terms via a distance test: the
softening keeps r² > 0, so exact-same-position pairs contribute a zero
numerator instead (diff = 0).

Grid: one program per TILE_A slice of block A; block B is streamed whole.
VMEM per step (TILE_A = 64, B ≤ 256): pos tiles ≈ 64·4·4 + 256·4·4 ≈ 5 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_A = 64
SOFTENING = 1e-2


def _nbody_kernel(pa_ref, ma_ref, pb_ref, mb_ref, out_ref):
    pa = pa_ref[...]  # (TA, 4) — xyz + padding lane
    ma = ma_ref[...]  # (TA, 1)
    pb = pb_ref[...]  # (B, 4)
    mb = mb_ref[...]  # (B, 1)
    diff = pb[None, :, :3] - pa[:, None, :3]  # (TA, B, 3)
    r2 = jnp.sum(diff * diff, axis=-1) + SOFTENING * SOFTENING
    inv_r3 = r2 ** (-1.5)
    s = ma[:, 0][:, None] * mb[:, 0][None, :] * inv_r3  # (TA, B)
    f = jnp.sum(s[:, :, None] * diff, axis=1)  # (TA, 3)
    out_ref[...] = jnp.pad(f, ((0, 0), (0, 1)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def nbody_tile(pos_a, mass_a, pos_b, mass_b, *, interpret=True):
    """Forces on block A from block B.

    pos_a: (A, 4) xyz+pad, mass_a: (A, 1), pos_b: (B, 4), mass_b: (B, 1).
    A must be a multiple of TILE_A. Returns (A, 4) with xyz forces + pad.
    Padded bodies must carry mass 0 (they then contribute nothing).
    """
    a = pos_a.shape[0]
    b = pos_b.shape[0]
    assert a % TILE_A == 0, "pad body count to tile multiple"
    grid = (a // TILE_A,)
    return pl.pallas_call(
        _nbody_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_A, 4), lambda i: (i, 0)),
            pl.BlockSpec((TILE_A, 1), lambda i: (i, 0)),
            pl.BlockSpec((b, 4), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_A, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, 4), jnp.float32),
        interpret=interpret,
    )(pos_a, mass_a, pos_b, mass_b)
