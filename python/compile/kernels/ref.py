"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

These functions define the exact semantics the Rust native backend
(`rust/src/pcit/{correlation,blocked}.rs`) and the Pallas kernels must
match. `EPS_GUARD` mirrors `quorall::pcit::EPS_GUARD`.
"""

import jax.numpy as jnp

EPS_GUARD = 1e-6


def corr_chunk_ref(za, zb):
    """Partial correlation chunk: plain ``za @ zb.T`` (no clamp).

    za: (A, M) standardized rows; zb: (B, M). The caller accumulates over M
    chunks and clamps to [-1, 1] afterwards, so the kernel itself is a pure
    matmul (MXU shape).
    """
    return jnp.matmul(za, zb.T, precision="highest")


def trio_eliminates_ref(rxy, rxz, ryz):
    """Vectorized PCIT trio test (see ``quorall::pcit::trio_eliminates``).

    All inputs broadcast together; returns a boolean array.
    Degenerate trios (|1 - r^2| < EPS_GUARD or any |r| < EPS_GUARD) never
    eliminate.
    """
    dxy = 1.0 - rxy * rxy
    dxz = 1.0 - rxz * rxz
    dyz = 1.0 - ryz * ryz
    ok = (
        (dxy >= EPS_GUARD)
        & (dxz >= EPS_GUARD)
        & (dyz >= EPS_GUARD)
        & (jnp.abs(rxy) >= EPS_GUARD)
        & (jnp.abs(rxz) >= EPS_GUARD)
        & (jnp.abs(ryz) >= EPS_GUARD)
    )
    # Guard the denominators so masked lanes never divide by ~0.
    safe_dxy = jnp.where(dxy >= EPS_GUARD, dxy, 1.0)
    safe_dxz = jnp.where(dxz >= EPS_GUARD, dxz, 1.0)
    safe_dyz = jnp.where(dyz >= EPS_GUARD, dyz, 1.0)
    safe_rxy = jnp.where(jnp.abs(rxy) >= EPS_GUARD, rxy, 1.0)
    safe_rxz = jnp.where(jnp.abs(rxz) >= EPS_GUARD, rxz, 1.0)
    safe_ryz = jnp.where(jnp.abs(ryz) >= EPS_GUARD, ryz, 1.0)
    pxy = (rxy - rxz * ryz) / jnp.sqrt(safe_dxz * safe_dyz)
    pxz = (rxz - rxy * ryz) / jnp.sqrt(safe_dxy * safe_dyz)
    pyz = (ryz - rxy * rxz) / jnp.sqrt(safe_dxy * safe_dxz)
    eps = (pxy / safe_rxy + pxz / safe_rxz + pyz / safe_ryz) / 3.0
    exy = jnp.abs(eps * rxz)
    ezy = jnp.abs(eps * ryz)
    return ok & (jnp.abs(rxy) < exy) & (jnp.abs(rxy) < ezy)


def pcit_chunk_ref(cxy, rxz, ryz):
    """PCIT elimination chunk.

    cxy: (A, B) direct correlations; rxz: (A, Z); ryz: (B, Z).
    Returns (A, B) float32 flags: 1.0 where ANY mediator z in the chunk
    eliminates the pair.
    """
    rxy = cxy[:, :, None]
    rx = rxz[:, None, :]
    ry = ryz[None, :, :]
    elim = trio_eliminates_ref(rxy, rx, ry)
    return jnp.any(elim, axis=-1).astype(jnp.float32)


def standardize_rows_ref(x):
    """Row standardization: (x - mean) / ||x - mean||_2 per row.

    Constant rows map to zero (correlation 0), matching the Rust reference.
    """
    mean = jnp.mean(x, axis=1, keepdims=True)
    centered = x - mean
    ss = jnp.sum(centered * centered, axis=1, keepdims=True)
    inv = jnp.where(ss > 0.0, 1.0 / jnp.sqrt(jnp.where(ss > 0.0, ss, 1.0)), 0.0)
    return centered * inv


def nbody_forces_ref(pos, mass, softening=1e-2):
    """Direct O(n^2) gravitational forces (for the nbody kernel)."""
    diff = pos[None, :, :] - pos[:, None, :]  # (N, N, 3): r_j - r_i
    r2 = jnp.sum(diff * diff, axis=-1) + softening * softening
    inv_r3 = r2 ** (-1.5)
    mm = mass[:, None] * mass[None, :]
    s = mm * inv_r3
    s = s * (1.0 - jnp.eye(pos.shape[0], dtype=pos.dtype))  # no self force
    return jnp.sum(s[:, :, None] * diff, axis=1)
