"""Pallas PCIT elimination kernel (L1): the O(N³) phase-2 hot spot.

TPU mapping (DESIGN.md §4): the trio scan is elementwise over an
(A, B, Z) broadcast — a VPU kernel, not an MXU one. The grid tiles the
(A, B) pair plane; the mediator axis Z is scanned *inside* the kernel in
ZSTEP-wide slabs with a carried OR-accumulator, bounding VMEM:

  per step: cxy tile 64·64·4 = 16 KiB, rxz slab 64·ZSTEP·4, ryz slab
  64·ZSTEP·4, flags 16 KiB, plus ~6 temporaries of 64·64·ZSTEP·4.
  ZSTEP = 8 → temporaries ≈ 6 × 128 KiB ≈ 0.8 MiB — comfortably in VMEM.

Semantics match `ref.pcit_chunk_ref` / `quorall::pcit::trio_eliminates`
exactly; degenerate trios (|1 − r²| < EPS_GUARD, |r| < EPS_GUARD) never
eliminate, which also self-masks the z = x / z = y diagonal (|r| = 1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS_GUARD = 1e-6
TILE_A = 64
TILE_B = 64
# Mediators processed per inner step (VMEM knob; see module docstring).
ZSTEP = 8


def _pcit_kernel(cxy_ref, rxz_ref, ryz_ref, out_ref):
    cxy = cxy_ref[...]  # (TA, TB)
    rxz = rxz_ref[...]  # (TA, Z)
    ryz = ryz_ref[...]  # (TB, Z)
    z = rxz.shape[1]
    assert z % ZSTEP == 0, "Z must be a multiple of ZSTEP"

    rxy = cxy[:, :, None]  # (TA, TB, 1)
    dxy = 1.0 - rxy * rxy
    rxy_ok = (dxy >= EPS_GUARD) & (jnp.abs(rxy) >= EPS_GUARD)
    safe_dxy = jnp.where(dxy >= EPS_GUARD, dxy, 1.0)
    safe_rxy = jnp.where(jnp.abs(rxy) >= EPS_GUARD, rxy, 1.0)

    def body(s, flags):
        rx = jax.lax.dynamic_slice_in_dim(rxz, s * ZSTEP, ZSTEP, axis=1)
        ry = jax.lax.dynamic_slice_in_dim(ryz, s * ZSTEP, ZSTEP, axis=1)
        rx = rx[:, None, :]  # (TA, 1, ZSTEP)
        ry = ry[None, :, :]  # (1, TB, ZSTEP)
        dxz = 1.0 - rx * rx
        dyz = 1.0 - ry * ry
        ok = (
            rxy_ok
            & (dxz >= EPS_GUARD)
            & (dyz >= EPS_GUARD)
            & (jnp.abs(rx) >= EPS_GUARD)
            & (jnp.abs(ry) >= EPS_GUARD)
        )
        sdxz = jnp.where(dxz >= EPS_GUARD, dxz, 1.0)
        sdyz = jnp.where(dyz >= EPS_GUARD, dyz, 1.0)
        srx = jnp.where(jnp.abs(rx) >= EPS_GUARD, rx, 1.0)
        sry = jnp.where(jnp.abs(ry) >= EPS_GUARD, ry, 1.0)
        pxy = (rxy - rx * ry) / jnp.sqrt(sdxz * sdyz)
        pxz = (rx - rxy * ry) / jnp.sqrt(safe_dxy * sdyz)
        pyz = (ry - rxy * rx) / jnp.sqrt(safe_dxy * sdxz)
        eps = (pxy / safe_rxy + pxz / srx + pyz / sry) / 3.0
        hit = ok & (jnp.abs(rxy) < jnp.abs(eps * rx)) & (jnp.abs(rxy) < jnp.abs(eps * ry))
        return flags | jnp.any(hit, axis=-1)

    flags = jax.lax.fori_loop(
        0, z // ZSTEP, body, jnp.zeros(cxy.shape, dtype=jnp.bool_)
    )
    out_ref[...] = flags.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pcit_chunk(cxy, rxz, ryz, *, interpret=True):
    """Pallas PCIT elimination over one mediator chunk.

    cxy: (A, B); rxz: (A, Z); ryz: (B, Z). A, B multiples of the 64-tile;
    Z a multiple of ZSTEP. Returns (A, B) float32 flags (1.0 = eliminated).
    """
    a, b = cxy.shape
    a2, z = rxz.shape
    b2, z2 = ryz.shape
    assert a == a2 and b == b2 and z == z2, "shape mismatch"
    assert a % TILE_A == 0 and b % TILE_B == 0, "pad to tile multiples"
    assert z % ZSTEP == 0, "pad Z to a multiple of ZSTEP"
    grid = (a // TILE_A, b // TILE_B)
    return pl.pallas_call(
        _pcit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_A, TILE_B), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_A, z), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_B, z), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_A, TILE_B), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=interpret,
    )(cxy, rxz, ryz)
