"""Pallas correlation kernel (L1): the PCIT phase-1 hot spot.

TPU mapping (DESIGN.md §4): the paper's OpenMP cache-blocked `Z·Zᵀ` becomes
an MXU-shaped tiled matmul. BlockSpec expresses the HBM↔VMEM schedule:
grid over (A/TA, B/TB) output tiles; each step streams a (TA, M) row panel
and a (TB, M) column panel into VMEM and issues one `dot_general` on the
systolic array.

VMEM budget per grid step (f32, TA = TB = 64, M = 128):
  in: 64·128·4 × 2 = 64 KiB,  out: 64·64·4 = 16 KiB  →  ~80 KiB ≪ 16 MiB.
The M (contraction) dimension stays whole inside a step — the caller (Rust
runtime / L2 model) accumulates across M chunks, keeping the artifact shape
static.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated against `ref.corr_chunk_ref` by
pytest, and the real-TPU tiling analysis lives in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile edges (MXU-friendly: multiples of the 128-lane register tile,
# halved to keep three buffers comfortably in VMEM at larger M).
TILE_A = 64
TILE_B = 64


def _corr_kernel(za_ref, zb_ref, out_ref):
    """One (TILE_A, TILE_B) output tile: za_tile @ zb_tile.T on the MXU."""
    za = za_ref[...]
    zb = zb_ref[...]
    out_ref[...] = jax.lax.dot_general(
        za,
        zb,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def corr_chunk(za, zb, *, interpret=True):
    """Pallas tiled ``za @ zb.T`` for standardized row panels.

    za: (A, M), zb: (B, M) with A % TILE_A == 0, B % TILE_B == 0.
    Returns (A, B) float32.
    """
    a, m = za.shape
    b, m2 = zb.shape
    assert m == m2, "sample dimension mismatch"
    assert a % TILE_A == 0 and b % TILE_B == 0, "pad to tile multiples"
    grid = (a // TILE_A, b // TILE_B)
    return pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_A, m), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_B, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_A, TILE_B), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=interpret,
    )(za, zb)
